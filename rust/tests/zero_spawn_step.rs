//! Zero-thread-spawn pinning for the resident-worker step engine: a full
//! three-phase SUMO `step_parallel` (project+EMA → batched orth →
//! limiter+apply) must synchronize on in-pool barriers only — no scoped or
//! ad-hoc thread creation per dispatch.
//!
//! Lives in its own test binary with a single `#[test]` so no concurrently
//! running test can construct pools and disturb either census — the same
//! isolation trick as `alloc_free_step.rs` uses for its allocation counter.

use sumo::config::{OptimCfg, OptimKind};
use sumo::linalg::Mat;
use sumo::optim;
use sumo::util::threadpool::{self, ThreadPool};
use sumo::util::Rng;

/// Kernel-level thread census (Linux); `None` elsewhere, where the
/// `threads_spawned` counter still covers pool-created threads.
fn os_thread_count() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("Threads:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

#[test]
fn three_phase_sumo_step_spawns_no_threads() {
    // Repeated moment shape classes so phase 2 runs a genuinely batched
    // orthogonalization, plus a dense norm layer for the Adam fallback.
    let mut shapes: Vec<(usize, usize)> = vec![(1, 32)];
    let mut projected = vec![false];
    for _ in 0..4 {
        shapes.push((64, 32));
        projected.push(true);
    }
    for _ in 0..3 {
        shapes.push((32, 64));
        projected.push(true);
    }
    shapes.push((48, 48));
    projected.push(true);
    let cfg = OptimCfg::new(OptimKind::Sumo)
        .with_lr(0.02)
        .with_rank(4)
        .with_update_freq(3);

    let _ = threadpool::global(); // settle the shared pool before the census
    let pool = ThreadPool::new(4);
    let mut opt = optim::build(&cfg, &shapes, &projected, 42);
    let mut wrng = Rng::new(7);
    let mut weights: Vec<Mat> = shapes
        .iter()
        .map(|&(m, n)| Mat::randn(m, n, 0.5, &mut wrng))
        .collect();
    let mut grng = Rng::new(8);
    let grads: Vec<Mat> = shapes
        .iter()
        .map(|&(m, n)| Mat::randn(m, n, 1.0, &mut grng))
        .collect();
    {
        // Warm-up: allocate moments and the per-class batch orth scratch.
        let mut refs: Vec<&mut Mat> = weights.iter_mut().collect();
        opt.step_parallel(&pool, &mut refs, &grads, 1.0);
        opt.end_step();
    }

    let spawned_before = threadpool::threads_spawned();
    let os_before = os_thread_count();
    for _ in 0..10 {
        let mut refs: Vec<&mut Mat> = weights.iter_mut().collect();
        opt.step_parallel(&pool, &mut refs, &grads, 1.0);
        opt.end_step();
    }
    assert_eq!(
        threadpool::threads_spawned(),
        spawned_before,
        "resident dispatch must not construct worker threads per step"
    );
    if let (Some(before), Some(after)) = (os_before, os_thread_count()) {
        assert_eq!(
            before, after,
            "OS thread count changed across three-phase steps: {before} -> {after}"
        );
    }
    for w in &weights {
        assert!(w.is_finite());
    }

    // Adaptive rank events may allocate (scratch regrow, group rebuild) but
    // must never spawn: refresh + residual measurement + rebuilt dispatch
    // all run on the same resident pool.
    let mut acfg = OptimCfg::new(OptimKind::Sumo)
        .with_lr(0.02)
        .with_rank(2)
        .with_update_freq(2)
        .with_adaptive_rank(2, 12)
        .with_residual_band(0.01, 0.05);
    acfg.rank_step = 4;
    let mut aopt = optim::build(&acfg, &shapes, &projected, 43);
    {
        let mut refs: Vec<&mut Mat> = weights.iter_mut().collect();
        aopt.step_parallel(&pool, &mut refs, &grads, 1.0);
        aopt.end_step();
    }
    let spawned_before = threadpool::threads_spawned();
    let os_before = os_thread_count();
    for _ in 0..8 {
        let mut refs: Vec<&mut Mat> = weights.iter_mut().collect();
        aopt.step_parallel(&pool, &mut refs, &grads, 1.0);
        aopt.end_step();
    }
    assert!(
        aopt.as_sumo().unwrap().rank_events() > 0,
        "adaptive run must cross a rank boundary"
    );
    assert_eq!(
        threadpool::threads_spawned(),
        spawned_before,
        "rank-event steps must not construct worker threads"
    );
    if let (Some(before), Some(after)) = (os_before, os_thread_count()) {
        assert_eq!(
            before, after,
            "OS thread count changed across rank-event steps: {before} -> {after}"
        );
    }
    for w in &weights {
        assert!(w.is_finite());
    }
}
