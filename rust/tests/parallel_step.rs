//! Coverage for the parallel per-layer step engine: the threaded dispatch
//! (`Optimizer::step_parallel` over `ThreadPool::par_for`) must produce
//! weights **bitwise identical** to the serial per-layer loop, for every
//! optimizer that overrides the threaded path (sumo, sumo-ns5, galore,
//! adam) and for the default serial fallback (muon).
//!
//! The companion zero-allocation scratch-reuse test lives in its own
//! binary (`tests/alloc_free_step.rs`) so its global allocation counter is
//! not polluted by concurrently running tests.

use sumo::config::{OptimCfg, OptimKind};
use sumo::linalg::Mat;
use sumo::optim;
use sumo::util::threadpool::ThreadPool;
use sumo::util::Rng;

/// A mixed model: a dense 1-D norm layer plus projected 2-D layers in both
/// orientations (left/right projection sides) and a square one.
fn layer_shapes() -> (Vec<(usize, usize)>, Vec<bool>) {
    (
        vec![(1, 32), (64, 32), (32, 64), (48, 48), (16, 8)],
        vec![false, true, true, true, true],
    )
}

fn run_pair(kind: OptimKind, workers: usize, steps: usize) {
    let (shapes, projected) = layer_shapes();
    let cfg = OptimCfg::new(kind)
        .with_lr(0.02)
        .with_rank(4)
        .with_update_freq(3);
    run_pair_with(&cfg, &shapes, &projected, workers, steps);
}

fn run_pair_with(
    cfg: &OptimCfg,
    shapes: &[(usize, usize)],
    projected: &[bool],
    workers: usize,
    steps: usize,
) {
    let kind = cfg.kind;
    let pool = ThreadPool::new(workers);
    let mut serial = optim::build(cfg, shapes, projected, 42);
    let mut par = optim::build(cfg, shapes, projected, 42);

    let mut wrng = Rng::new(7);
    let mut w_serial: Vec<Mat> = shapes
        .iter()
        .map(|&(m, n)| Mat::randn(m, n, 0.5, &mut wrng))
        .collect();
    let mut w_par = w_serial.clone();

    let mut grng = Rng::new(8);
    for _step in 0..steps {
        let grads: Vec<Mat> = shapes
            .iter()
            .map(|&(m, n)| Mat::randn(m, n, 1.0, &mut grng))
            .collect();
        for (i, (w, g)) in w_serial.iter_mut().zip(&grads).enumerate() {
            serial.step(i, w, g, 1.0);
        }
        serial.end_step();
        let mut refs: Vec<&mut Mat> = w_par.iter_mut().collect();
        par.step_parallel(&pool, &mut refs, &grads, 1.0);
        par.end_step();
    }

    for (i, (a, b)) in w_serial.iter().zip(&w_par).enumerate() {
        assert!(a.is_finite(), "{kind:?} layer {i} not finite");
        assert_eq!(
            a.max_diff(b),
            0.0,
            "{kind:?} layer {i}: threaded step diverged from serial"
        );
    }
}

#[test]
fn sumo_threaded_matches_serial_bitwise() {
    run_pair(OptimKind::Sumo, 4, 10);
}

#[test]
fn sumo_ns5_threaded_matches_serial_bitwise() {
    run_pair(OptimKind::SumoNs5, 4, 10);
}

#[test]
fn galore_threaded_matches_serial_bitwise() {
    run_pair(OptimKind::GaLore, 4, 10);
}

#[test]
fn adam_threaded_matches_serial_bitwise() {
    run_pair(OptimKind::Adam, 4, 10);
}

#[test]
fn default_serial_fallback_matches_too() {
    // Muon has no threaded override; the trait's default must still agree.
    run_pair(OptimKind::Muon, 4, 6);
}

#[test]
fn single_worker_pool_degenerates_to_serial() {
    run_pair(OptimKind::Sumo, 1, 6);
}

#[test]
fn sumo_matches_serial_across_resident_pool_sizes() {
    for workers in [1usize, 2, 8] {
        run_pair(OptimKind::Sumo, workers, 6);
    }
}

#[test]
fn galore_matches_serial_across_resident_pool_sizes() {
    for workers in [1usize, 2, 8] {
        run_pair(OptimKind::GaLore, workers, 6);
    }
}

#[test]
fn adam_matches_serial_across_resident_pool_sizes() {
    for workers in [1usize, 2, 8] {
        run_pair(OptimKind::Adam, workers, 6);
    }
}

#[test]
fn nested_par_for_from_worker_does_not_deadlock() {
    // A dispatch issued from inside a resident worker must run inline —
    // re-entering the in-pool barrier would deadlock. Hammer it across
    // rounds so a racy epoch handshake (lost wakeup, double participation)
    // would be caught as a hang or a miscount.
    use std::sync::atomic::{AtomicUsize, Ordering};
    let pool = ThreadPool::new(4);
    let hits: Vec<AtomicUsize> = (0..48 * 16).map(|_| AtomicUsize::new(0)).collect();
    let rounds = 25;
    for _ in 0..rounds {
        pool.par_for(48, |i| {
            pool.par_for(16, |j| {
                hits[i * 16 + j].fetch_add(1, Ordering::SeqCst);
            });
        });
    }
    assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == rounds));
    // Nested mutable-dispatch variants route through par_for and must also
    // run inline from a worker.
    let mut grid: Vec<Vec<u64>> = (0..32).map(|_| vec![0u64; 8]).collect();
    pool.par_for_each_mut(&mut grid, |_, row| {
        pool.par_for_each_chunk_mut(row, |start, chunk| {
            for (off, x) in chunk.iter_mut().enumerate() {
                *x = (start + off) as u64 + 1;
            }
        });
    });
    assert!(grid
        .iter()
        .all(|row| row.iter().enumerate().all(|(j, &x)| x == j as u64 + 1)));
}

#[test]
fn sumo_three_phase_grouped_dispatch_matches_serial_with_shape_classes() {
    // Many layers sharing moment shape classes — six (64,32) left-projected
    // and five (32,64) right-projected layers all land in the (4,32) class,
    // so phase 2 runs a genuinely multi-problem batched orthogonalization
    // with mixed orientations; (48,48) gets its own class and a dense norm
    // layer rides along. Weight decay on, so the Block-4 pre-update decay
    // ordering is also pinned across both paths.
    let mut shapes: Vec<(usize, usize)> = vec![(1, 32)];
    let mut projected = vec![false];
    for _ in 0..6 {
        shapes.push((64, 32));
        projected.push(true);
    }
    for _ in 0..5 {
        shapes.push((32, 64));
        projected.push(true);
    }
    shapes.push((48, 48));
    projected.push(true);
    let mut cfg = OptimCfg::new(OptimKind::Sumo)
        .with_lr(0.02)
        .with_rank(4)
        .with_update_freq(3);
    cfg.weight_decay = 0.05;
    run_pair_with(&cfg, &shapes, &projected, 4, 10);
    // Single worker exercises the inline (non-chunked) batched path.
    run_pair_with(&cfg, &shapes, &projected, 1, 6);
}

#[test]
fn galore_threaded_matches_serial_with_decay() {
    let (shapes, projected) = layer_shapes();
    let mut cfg = OptimCfg::new(OptimKind::GaLore)
        .with_lr(0.02)
        .with_rank(4)
        .with_update_freq(3);
    cfg.weight_decay = 0.05;
    run_pair_with(&cfg, &shapes, &projected, 4, 8);
}

#[test]
fn threaded_path_converges_on_quadratic() {
    // End-to-end sanity: the threaded engine actually optimizes.
    let pool = ThreadPool::new(3);
    let shapes = vec![(32usize, 16usize)];
    let projected = vec![true];
    let cfg = OptimCfg::new(OptimKind::Sumo)
        .with_lr(0.05)
        .with_rank(4)
        .with_update_freq(5);
    let mut opt = optim::build(&cfg, &shapes, &projected, 1);
    let mut rng = Rng::new(11);
    let target = Mat::randn(32, 16, 1.0, &mut rng);
    let mut w = vec![Mat::zeros(32, 16)];
    let l0 = target.sumsq();
    for _ in 0..200 {
        let mut g = w[0].clone();
        g.axpy(-1.0, &target);
        let grads = vec![g];
        let mut refs: Vec<&mut Mat> = w.iter_mut().collect();
        opt.step_parallel(&pool, &mut refs, &grads, 1.0);
        opt.end_step();
    }
    let mut diff = w[0].clone();
    diff.axpy(-1.0, &target);
    assert!(diff.sumsq() < 0.35 * l0, "loss {l0} -> {}", diff.sumsq());
}
