//! Integration tests over the PJRT runtime: every artifact class loads,
//! executes, and matches its native-Rust twin.
//!
//! Requires `make artifacts` (skipped gracefully if artifacts are absent).

use sumo::config::{OptimCfg, OptimKind};
use sumo::coordinator::Coordinator;
use sumo::data::{Batcher, SyntheticCorpus};
use sumo::linalg::{newton_schulz5, orth_svd, Mat};
use sumo::model::ParamStore;
use sumo::runtime::literal::{literal_to_mat, mat_to_literal};
use sumo::runtime::{ModelRunner, Runtime};
use sumo::util::Rng;

fn runtime() -> Option<Runtime> {
    match Runtime::from_default_artifacts() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping runtime tests: {e}");
            None
        }
    }
}

#[test]
fn kernel_orth_svd_matches_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(1);
    let m = Mat::randn(8, 64, 1.0, &mut rng);
    let outs = rt
        .run("orth_svd_8x64.hlo.txt", &[mat_to_literal(&m).unwrap()])
        .unwrap();
    let hlo = literal_to_mat(&outs[0], 8, 64).unwrap();
    let native = orth_svd(&m);
    assert!(
        hlo.max_diff(&native) < 2e-3,
        "HLO vs native orth: {}",
        hlo.max_diff(&native)
    );
}

#[test]
fn kernel_ns5_matches_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(2);
    let m = Mat::randn(8, 64, 1.0, &mut rng);
    let outs = rt
        .run("ns5_8x64.hlo.txt", &[mat_to_literal(&m).unwrap()])
        .unwrap();
    let hlo = literal_to_mat(&outs[0], 8, 64).unwrap();
    let native = newton_schulz5(&m, 5);
    assert!(
        hlo.max_diff(&native) < 2e-3,
        "HLO vs native ns5: {}",
        hlo.max_diff(&native)
    );
}

#[test]
fn model_runner_param_specs_agree_with_manifest() {
    let Some(rt) = runtime() else { return };
    // Constructor itself asserts manifest == ModelCfg::param_specs.
    for id in ["nano_lm", "nano_cls2", "micro_lm", "small_lm"] {
        ModelRunner::new(&rt, id).unwrap();
    }
}

#[test]
fn train_step_runs_and_loss_is_sane() {
    let Some(rt) = runtime() else { return };
    let runner = ModelRunner::new(&rt, "nano_lm").unwrap();
    let params = ParamStore::init(&runner.cfg, 3);
    let corpus = SyntheticCorpus::new(runner.cfg.vocab, 4);
    let mut batcher = Batcher::new(corpus, runner.batch, runner.seq_len());
    let out = runner.train_step(&params, &batcher.next()).unwrap();
    // Fresh model on a 256-vocab: CE ≈ ln 256 ≈ 5.55.
    assert!((out.loss - (runner.cfg.vocab as f32).ln()).abs() < 1.0, "loss {}", out.loss);
    assert_eq!(out.grads.len(), params.len());
    for ((name, p), g) in params.tensors.iter().zip(&out.grads) {
        assert_eq!(p.shape(), g.shape(), "{name}");
        assert!(g.is_finite(), "{name} grad finite");
    }
    // Embedding gradient must be nonzero (tied head guarantees signal).
    assert!(out.grads[0].fro() > 0.0);
}

#[test]
fn eval_loss_matches_train_loss_at_same_params() {
    let Some(rt) = runtime() else { return };
    let runner = ModelRunner::new(&rt, "nano_lm").unwrap();
    let params = ParamStore::init(&runner.cfg, 5);
    let corpus = SyntheticCorpus::new(runner.cfg.vocab, 6);
    let mut batcher = Batcher::new(corpus, runner.batch, runner.seq_len());
    let batch = batcher.next();
    let train = runner.train_step(&params, &batch).unwrap();
    let eval = runner.eval_loss(&params, &batch).unwrap();
    assert!((train.loss - eval).abs() < 1e-4, "{} vs {}", train.loss, eval);
}

#[test]
fn hlo_sumo_engine_matches_native_sumo_one_step() {
    let Some(rt) = runtime() else { return };
    // Native and HLO coordinators from identical seeds and identical data:
    // after one iteration the weights must agree closely. (The rSVD bases
    // use independent Gaussian draws, so we compare through the *projector*
    // Q Qᵀ-invariant weight update by running with update_freq=1 and the
    // same seed: the Omega draws differ, so we assert loss-level agreement
    // after a few steps instead of bitwise weights.)
    let cfg = OptimCfg::new(OptimKind::Sumo).with_lr(0.02).with_rank(4).with_update_freq(2);
    let make_batches = |seed| {
        let corpus = SyntheticCorpus::new(256, seed);
        let mut b = Batcher::new(corpus, 8, 32);
        (0..6).map(|_| b.next()).collect::<Vec<_>>()
    };
    let mut native = Coordinator::native(&rt, "nano_lm", &cfg, 11, 1).unwrap();
    let mut hlo = Coordinator::hlo_sumo(&rt, "nano_lm", &cfg, 11).unwrap();
    let batches = make_batches(77);
    let mut native_losses = Vec::new();
    let mut hlo_losses = Vec::new();
    for b in &batches {
        native_losses.push(native.train_iteration(b, 1.0).unwrap().loss);
        hlo_losses.push(hlo.train_iteration(b, 1.0).unwrap().loss);
    }
    // Same init, same data: first loss identical.
    assert!((native_losses[0] - hlo_losses[0]).abs() < 1e-4);
    // Trajectories stay close (both are exact SVD SUMO; only the random
    // sketches differ).
    for (a, b) in native_losses.iter().zip(&hlo_losses) {
        assert!((a - b).abs() < 0.15, "native {native_losses:?} hlo {hlo_losses:?}");
    }
}

#[test]
fn dp_fallback_is_counted_and_sharded_path_is_not() {
    let Some(rt) = runtime() else { return };
    let cfg = OptimCfg::new(OptimKind::Sumo).with_lr(0.02).with_rank(4).with_update_freq(2);
    // dp=2 with the (even) artifact batch: shards, no fallback counted.
    let mut coord = Coordinator::native(&rt, "nano_lm", &cfg, 11, 2).unwrap();
    let corpus = SyntheticCorpus::new(coord.runner.cfg.vocab, 3);
    let mut batcher = Batcher::new(corpus, coord.runner.batch, coord.runner.seq_len());
    assert_eq!(coord.runner.batch % 2, 0, "artifact batch assumed even");
    coord.train_iteration(&batcher.next(), 1.0).unwrap();
    assert_eq!(coord.dp_fallback_count(), 0);
    // dp = batch+1 can never divide: every iteration counts a fallback.
    let dp = coord.runner.batch + 1;
    let mut coord = Coordinator::native(&rt, "nano_lm", &cfg, 11, dp).unwrap();
    let corpus = SyntheticCorpus::new(coord.runner.cfg.vocab, 3);
    let mut batcher = Batcher::new(corpus, coord.runner.batch, coord.runner.seq_len());
    coord.train_iteration(&batcher.next(), 1.0).unwrap();
    coord.train_iteration(&batcher.next(), 1.0).unwrap();
    assert_eq!(coord.dp_fallback_count(), 2);
}

#[test]
fn cls_train_and_eval_roundtrip() {
    let Some(rt) = runtime() else { return };
    let runner = ModelRunner::new(&rt, "nano_cls2").unwrap();
    let params = ParamStore::init(&runner.cfg, 9);
    let task = sumo::data::glue::GlueTask::by_name("RTE", runner.cfg.vocab, runner.seq_len())
        .unwrap();
    let (toks, labels) = task.batch("train", 0, runner.batch);
    let out = runner.train_step_labeled(&params, &toks, &labels).unwrap();
    assert!(out.loss.is_finite() && out.loss > 0.0);
    let (loss, logits) = runner.eval_labeled(&params, &toks, &labels).unwrap();
    assert!(loss.is_finite());
    assert_eq!(logits.len(), runner.batch);
    assert_eq!(logits[0].len(), 2);
}

#[test]
fn sumo_update_artifact_matches_native_blocks234() {
    // Drive the sumo_update artifact directly with a *fixed* Q and compare
    // against the native Block 2-4 math (removes rSVD randomness entirely).
    let Some(rt) = runtime() else { return };
    let (m, n, r) = (256usize, 64usize, 4usize);
    let mut rng = Rng::new(13);
    let w = Mat::randn(m, n, 0.1, &mut rng);
    let g = Mat::randn(m, n, 1.0, &mut rng);
    let raw = Mat::randn(m, r, 1.0, &mut rng);
    let (q, _) = sumo::linalg::mgs_qr(&raw);
    let mom = Mat::randn(r, n, 0.5, &mut rng);
    let (lr, beta, wd, gamma, alpha, o_prev) = (0.01f32, 0.9f32, 0.05f32, 1.1f32, 1.0f32, 2.0f32);
    let outs = rt
        .run(
            "sumo_update_256x64_r4.hlo.txt",
            &[
                mat_to_literal(&w).unwrap(),
                mat_to_literal(&mom).unwrap(),
                mat_to_literal(&q).unwrap(),
                mat_to_literal(&g).unwrap(),
                xla::Literal::scalar(o_prev),
                xla::Literal::scalar(lr),
                xla::Literal::scalar(beta),
                xla::Literal::scalar(wd),
                xla::Literal::scalar(gamma),
                xla::Literal::scalar(alpha),
            ],
        )
        .unwrap();
    let w_hlo = literal_to_mat(&outs[0], m, n).unwrap();
    // Native twin.
    let ghat = sumo::linalg::matmul_at_b(&q, &g);
    let mut mom_new = mom.clone();
    mom_new.ema(beta, 1.0 - beta, &ghat);
    let mut o = orth_svd(&mom_new);
    let o_norm = o.fro();
    if o_prev > 0.0 && o_norm / o_prev > gamma {
        o.scale(gamma * o_prev / o_norm);
    }
    let full = sumo::linalg::matmul(&q, &o);
    let scale = 0.2 * (m.max(n) as f32).sqrt();
    let mut w_native = w.clone();
    w_native.axpy(-lr * alpha * scale, &full);
    let mut decay = w.clone();
    decay.scale(lr * wd);
    w_native.axpy(-1.0, &decay);
    assert!(
        w_hlo.max_diff(&w_native) < 2e-3,
        "HLO vs native sumo update: {}",
        w_hlo.max_diff(&w_native)
    );
    let mom_hlo = literal_to_mat(&outs[1], r, n).unwrap();
    assert!(mom_hlo.max_diff(&mom_new) < 1e-4);
}
