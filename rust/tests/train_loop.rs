//! Full training-loop integration: coordinator + trainer + checkpoints +
//! data-parallel shards over the real PJRT runtime.

use sumo::config::{OptimCfg, OptimKind, Schedule, TrainCfg};
use sumo::coordinator::Coordinator;
use sumo::data::glue::GlueTask;
use sumo::model::checkpoint;
use sumo::runtime::Runtime;
use sumo::train::Trainer;

fn runtime() -> Option<Runtime> {
    match Runtime::from_default_artifacts() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping train-loop tests: {e}");
            None
        }
    }
}

fn quick_cfg(steps: usize) -> TrainCfg {
    TrainCfg {
        steps,
        eval_batches: 2,
        log_every: 1000,
        schedule: Schedule::Constant,
        ..TrainCfg::default()
    }
}

#[test]
fn pretrain_loss_decreases_for_every_optimizer() {
    let Some(rt) = runtime() else { return };
    for kind in [
        OptimKind::Sumo,
        OptimKind::SumoNs5,
        OptimKind::GaLore,
        OptimKind::Adam,
        OptimKind::Muon,
        OptimKind::Lora,
        OptimKind::ReLora,
        OptimKind::LowRank,
        OptimKind::Sgd,
        OptimKind::Osgdm,
    ] {
        let ocfg = OptimCfg {
            lr: sumo::cli::commands::default_lr(kind),
            rank: 4,
            update_freq: 10,
            ..OptimCfg::new(kind)
        };
        let mut coord = Coordinator::native(&rt, "nano_lm", &ocfg, 42, 1).unwrap();
        let report = Trainer::new(quick_cfg(25)).pretrain(&mut coord, None).unwrap();
        let init_loss = (coord.runner.cfg.vocab as f32).ln();
        assert!(
            report.val_loss < init_loss + 0.05,
            "{:?}: val_loss {} should not exceed init {init_loss}",
            kind,
            report.val_loss
        );
        assert!(report.final_loss.is_finite(), "{kind:?} diverged");
    }
}

#[test]
fn checkpoint_roundtrip_preserves_eval_loss() {
    let Some(rt) = runtime() else { return };
    let ocfg = OptimCfg::new(OptimKind::Sumo).with_lr(0.02).with_rank(4).with_update_freq(5);
    let mut coord = Coordinator::native(&rt, "nano_lm", &ocfg, 7, 1).unwrap();
    let trainer = Trainer::new(quick_cfg(10));
    let report = trainer.pretrain(&mut coord, None).unwrap();
    let dir = std::env::temp_dir().join("sumo_traintest");
    let path = dir.join("ck.bin");
    checkpoint::save(&coord.params, 10, &path).unwrap();
    let (loaded, step) = checkpoint::load(&path).unwrap();
    assert_eq!(step, 10);
    let mut coord2 = Coordinator::native(&rt, "nano_lm", &ocfg, 99, 1).unwrap();
    coord2.set_params(loaded);
    // Same eval stream => identical loss.
    let corpus = sumo::data::SyntheticCorpus::new(coord2.runner.cfg.vocab, 42 ^ 0xEEE);
    let mut b = sumo::data::Batcher::new(corpus, coord2.runner.batch, coord2.runner.seq_len());
    let batch = b.next();
    let l2 = coord2.runner.eval_loss(&coord2.params, &batch).unwrap();
    let l1 = coord.runner.eval_loss(&coord.params, &batch).unwrap();
    assert!((l1 - l2).abs() < 1e-5, "{l1} vs {l2} (report {})", report.val_loss);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn dp_shards_change_gradient_semantics_not_stability() {
    let Some(rt) = runtime() else { return };
    let ocfg = OptimCfg::new(OptimKind::Adam).with_lr(2e-3);
    let mut coord = Coordinator::native(&rt, "nano_lm", &ocfg, 3, 2).unwrap();
    assert_eq!(coord.dp_shards, 2);
    let report = Trainer::new(quick_cfg(6)).pretrain(&mut coord, None).unwrap();
    assert!(report.final_loss.is_finite());
}

#[test]
fn finetune_beats_chance_on_easy_task() {
    let Some(rt) = runtime() else { return };
    let ocfg = OptimCfg::new(OptimKind::Sumo).with_lr(0.02).with_rank(4).with_update_freq(20);
    let mut coord = Coordinator::native(&rt, "nano_cls2", &ocfg, 21, 1).unwrap();
    // An easy high-signal binary task on the nano vocab/seq.
    let task = GlueTask {
        signal: 0.3,
        ..GlueTask::by_name("SST2", coord.runner.cfg.vocab, coord.runner.seq_len()).unwrap()
    };
    let tcfg = TrainCfg {
        steps: 60,
        eval_batches: 6,
        log_every: 1000,
        eval_every: 0,
        ..TrainCfg::default()
    };
    let report = Trainer::new(tcfg).finetune_glue(&mut coord, &task).unwrap();
    assert!(
        report.metric > 0.7,
        "easy task should beat chance clearly: acc={}",
        report.metric
    );
}

#[test]
fn optimizer_state_memory_ordering_in_vivo() {
    // Measured (not analytic) state bytes: SUMO < GaLore < Adam on the
    // same model — Table 1's ordering realized end-to-end.
    let Some(rt) = runtime() else { return };
    let mut sizes = std::collections::BTreeMap::new();
    for kind in [OptimKind::Sumo, OptimKind::GaLore, OptimKind::Adam] {
        let ocfg = OptimCfg::new(kind).with_rank(4).with_update_freq(10);
        let mut coord = Coordinator::native(&rt, "nano_lm", &ocfg, 1, 1).unwrap();
        Trainer::new(quick_cfg(3)).pretrain(&mut coord, None).unwrap();
        sizes.insert(format!("{kind:?}"), coord.optimizer_state_bytes());
    }
    assert!(sizes["Sumo"] < sizes["GaLore"], "{sizes:?}");
    assert!(sizes["GaLore"] < sizes["Adam"], "{sizes:?}");
}
