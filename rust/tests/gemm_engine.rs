//! Property suite for the packed/tiled GEMM engine (`linalg::matmul`):
//!
//! * every orientation (A·B, Aᵀ·B, A·Bᵀ) against an f64 naive reference
//!   across a shape sweep that includes degenerate cases — 0-row/0-col
//!   outputs, 1-row, k = 0, and sub-microtile remainders (n < NR, m < MR);
//! * α/β fusion and the per-element epilogue closure;
//! * **pool-size bitwise invariance**: dispatching the tile loop across
//!   resident pools of size {1, 2, 8} must produce results bitwise
//!   identical to the serial path, mirroring the step-engine sweeps in
//!   `tests/parallel_step.rs` — tile geometry depends only on the problem
//!   shape, never on the worker count.

use sumo::linalg::{gemm_into, gemm_pooled_into, GemmOp, GemmScratch, Mat};
use sumo::util::threadpool::ThreadPool;
use sumo::util::Rng;

/// f64 reference for C = α·op(A, B) + β·C₀.
fn reference(op: GemmOp, alpha: f32, a: &Mat, b: &Mat, beta: f32, c0: &Mat) -> Mat {
    let (m, k, n) = match op {
        GemmOp::Nn => (a.rows, a.cols, b.cols),
        GemmOp::Tn => (a.cols, a.rows, b.cols),
        GemmOp::Nt => (a.rows, a.cols, b.rows),
    };
    let mut c = Mat::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0f64;
            for kk in 0..k {
                let av = match op {
                    GemmOp::Nn | GemmOp::Nt => a[(i, kk)],
                    GemmOp::Tn => a[(kk, i)],
                } as f64;
                let bv = match op {
                    GemmOp::Nn | GemmOp::Tn => b[(kk, j)],
                    GemmOp::Nt => b[(j, kk)],
                } as f64;
                s += av * bv;
            }
            c[(i, j)] = (alpha as f64 * s + beta as f64 * c0[(i, j)] as f64) as f32;
        }
    }
    c
}

/// Build (A, B) with logical GEMM dims (m, k, n) for an orientation.
fn operands(op: GemmOp, m: usize, k: usize, n: usize, rng: &mut Rng) -> (Mat, Mat) {
    match op {
        GemmOp::Nn => (Mat::randn(m, k, 1.0, rng), Mat::randn(k, n, 1.0, rng)),
        GemmOp::Tn => (Mat::randn(k, m, 1.0, rng), Mat::randn(k, n, 1.0, rng)),
        GemmOp::Nt => (Mat::randn(m, k, 1.0, rng), Mat::randn(n, k, 1.0, rng)),
    }
}

const OPS: [GemmOp; 3] = [GemmOp::Nn, GemmOp::Tn, GemmOp::Nt];

/// Shape sweep: degenerate rows/cols/contraction, sub-microtile remainders
/// (MR = 4, NR = 8), multi-tile (MC = 128, NC = 64), and multi-Kc-block
/// (KC = 256) problems, plus the SUMO step's tall-skinny profile.
const SHAPES: [(usize, usize, usize); 12] = [
    (0, 3, 4),
    (4, 3, 0),
    (5, 0, 7),
    (1, 1, 1),
    (1, 17, 5),
    (3, 5, 2),
    (7, 9, 6),
    (17, 300, 23),
    (64, 32, 48),
    (130, 70, 33),
    (140, 260, 70),
    (256, 16, 40),
];

#[test]
fn all_orientations_match_f64_reference() {
    let mut rng = Rng::new(101);
    for &op in &OPS {
        let mut ws = GemmScratch::new();
        for &(m, k, n) in &SHAPES {
            let (a, b) = operands(op, m, k, n, &mut rng);
            let c0 = Mat::randn(m, n, 1.0, &mut rng);
            for &(alpha, beta) in &[(1.0f32, 0.0f32), (-0.5, 0.8), (2.0, 1.0)] {
                let mut c = c0.clone();
                gemm_into(op, alpha, &a, &b, beta, &mut c, &mut ws);
                let want = reference(op, alpha, &a, &b, beta, &c0);
                let tol = 1e-4 * (1.0 + (k as f32).sqrt());
                assert!(
                    c.max_diff(&want) < tol,
                    "{op:?} ({m},{k},{n}) α={alpha} β={beta}: diff={}",
                    c.max_diff(&want)
                );
            }
        }
    }
}

#[test]
fn beta_zero_overwrites_nan_poisoned_output() {
    // β = 0 must *write* the output without reading it: seed C with NaN in
    // every orientation and require a clean result (also exercises the
    // NaN-propagating `max_diff` — a swallowed NaN would pass silently).
    let mut rng = Rng::new(103);
    let mut ws = GemmScratch::new();
    for &op in &OPS {
        let (a, b) = operands(op, 33, 20, 11, &mut rng);
        let mut c = Mat::zeros(33, 11);
        c.data.iter_mut().for_each(|x| *x = f32::NAN);
        gemm_into(op, 1.0, &a, &b, 0.0, &mut c, &mut ws);
        assert!(c.is_finite(), "{op:?}: β=0 read stale NaN output");
        let want = reference(op, 1.0, &a, &b, 0.0, &Mat::zeros(33, 11));
        assert!(c.max_diff(&want) < 1e-3);
    }
}

#[test]
fn pool_sizes_are_bitwise_invariant() {
    // Mirrors the parallel_step.rs sweep: serial vs pools {1, 2, 8} must be
    // bitwise identical on multi-tile shapes, every orientation, α/β on.
    let mut rng = Rng::new(107);
    let shapes = [(300usize, 40usize, 70usize), (130, 257, 9), (64, 32, 48), (512, 16, 200)];
    for &op in &OPS {
        for &(m, k, n) in &shapes {
            let (a, b) = operands(op, m, k, n, &mut rng);
            let c0 = Mat::randn(m, n, 1.0, &mut rng);
            let mut serial = c0.clone();
            let mut ws = GemmScratch::new();
            gemm_pooled_into(op, -0.3, &a, &b, 0.9, &mut serial, &mut ws, None);
            for workers in [1usize, 2, 8] {
                let pool = ThreadPool::new(workers);
                let mut pooled = c0.clone();
                gemm_pooled_into(op, -0.3, &a, &b, 0.9, &mut pooled, &mut ws, Some(&pool));
                assert_eq!(
                    serial.data, pooled.data,
                    "{op:?} ({m},{k},{n}) pool size {workers} diverged bitwise from serial"
                );
            }
        }
    }
}

#[test]
fn legacy_entry_points_agree_with_each_other() {
    // matmul / matmul_at_b / matmul_a_bt route through the same core: the
    // orientation variants must agree with explicit transposition exactly
    // (same packing-folded arithmetic, same tile geometry).
    let mut rng = Rng::new(109);
    let a = Mat::randn(37, 21, 1.0, &mut rng);
    let b = Mat::randn(21, 13, 1.0, &mut rng);
    let nn = sumo::linalg::matmul(&a, &b);
    let tn = sumo::linalg::matmul_at_b(&a.t(), &b);
    let nt = sumo::linalg::matmul_a_bt(&a, &b.t());
    assert_eq!(nn.data, tn.data, "Tn packing diverged from Nn");
    assert_eq!(nn.data, nt.data, "Nt packing diverged from Nn");
}
