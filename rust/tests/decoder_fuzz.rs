//! Deterministic structure-aware fuzzing of every hostile byte surface.
//!
//! Seeded `Rng`-driven mutations (truncation, length-field inflation, tag
//! corruption, random byte flips) over valid wire frames, checkpoint and
//! shard files, config JSON, and chaos fault specs. The contract under
//! test is the crate's validate-before-allocate discipline: every
//! guaranteed-bad mutant must produce a clean `Err` — never a panic, and
//! never an allocation larger than the surface's documented cap. Byte
//! flips that may legally decode still get the no-panic /
//! bounded-allocation guarantee.
//!
//! The max-allocation tracker is a process-global allocator (same pattern
//! as `alloc_free_step.rs`), so everything runs inside one `#[test]` in its
//! own integration-test binary: concurrent tests would pollute the
//! high-water mark.

use std::alloc::{GlobalAlloc, Layout, System};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};

use sumo::cluster::chaos::{ChaosSpec, MAX_FAULTS};
use sumo::cluster::codec::{decode_mats, encode_mats, GradCodec};
use sumo::cluster::messages::{self, Msg, HEADER_BYTES, MAX_FRAME_BYTES};
use sumo::cluster::shard::{self, ShardMeta};
use sumo::config::{ClusterCfg, ModelCfg, OptimCfg, OptimKind};
use sumo::linalg::Mat;
use sumo::model::{checkpoint, ParamStore};
use sumo::util::json::Json;
use sumo::util::Rng;

// ---------------------------------------------------------------------------
// Max-single-allocation tracker.
// ---------------------------------------------------------------------------

struct TrackingAlloc;

static MAX_ALLOC: AtomicU64 = AtomicU64::new(0);

// Edition 2021: the bodies of `unsafe fn`s are implicitly unsafe blocks.
unsafe impl GlobalAlloc for TrackingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        MAX_ALLOC.fetch_max(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        MAX_ALLOC.fetch_max(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        MAX_ALLOC.fetch_max(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: TrackingAlloc = TrackingAlloc;

/// Generous bound for surfaces whose decode allocations are tied to the
/// (small) input size: far over anything a legitimate decode of our tiny
/// fixtures needs, far under an attacker-controlled multi-GB allocation.
const GENERAL_CAP: u64 = 1 << 26;

/// Run `f`, asserting it neither panics nor allocates a single block larger
/// than `cap`; returns whether it succeeded (`Ok`).
fn guarded<T, F: FnOnce() -> sumo::Result<T>>(label: &str, cap: u64, f: F) -> bool {
    MAX_ALLOC.store(0, Ordering::SeqCst);
    let outcome = catch_unwind(AssertUnwindSafe(f));
    let peak = MAX_ALLOC.load(Ordering::SeqCst);
    let res = match outcome {
        Ok(r) => r,
        Err(_) => panic!("{label}: decoder panicked on hostile input"),
    };
    assert!(peak <= cap, "{label}: allocated {peak} bytes (cap {cap}) on hostile input");
    res.is_ok()
}

/// Like [`guarded`] but the mutant must be rejected.
fn must_err<T, F: FnOnce() -> sumo::Result<T>>(label: &str, cap: u64, f: F) {
    assert!(!guarded(label, cap, f), "{label}: hostile mutant decoded Ok");
}

// ---------------------------------------------------------------------------
// Fixtures.
// ---------------------------------------------------------------------------

fn sample_msgs(rng: &mut Rng) -> Vec<Msg> {
    let mats = vec![Mat::randn(3, 2, 1.0, rng), Mat::randn(1, 4, 1.0, rng)];
    let grads = encode_mats(GradCodec::Raw, &mats);
    vec![
        Msg::Hello { worker_id: 3, task_support: 3, codec: 0 },
        Msg::GroupState { step: 7, mats: mats.clone() },
        Msg::SyncWeights { start_step: 2, ckpt_base: 1, mats },
        Msg::Grads { step: 9, shard: 1, loss: 0.5, grads: grads.clone() },
        Msg::ReducedGrads { step: 9, loss: 0.25, grads },
        Msg::Checkpoint { step: 11, owners: vec![(0, 0, 1), (1, 1, 2)] },
        Msg::Ack { step: 1 },
        Msg::KillAll,
        Msg::Shutdown { reason: "bye".into() },
        Msg::Reassign {
            start_step: 4,
            permanent: true,
            shards: vec![0, 2, 5],
            group_start: 1,
            group_end: 2,
        },
        Msg::Leave { worker_id: 2 },
    ]
}

fn scratch_dir() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("sumo_decoder_fuzz_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

// ---------------------------------------------------------------------------
// Surface 1: wire frames (`messages::decode` + `messages::read_msg`).
// ---------------------------------------------------------------------------

fn fuzz_wire(rng: &mut Rng) {
    let msgs = sample_msgs(rng);
    for msg in &msgs {
        let frame = messages::encode(msg);
        let payload_len = frame.len() - HEADER_BYTES;

        // Every strict truncation must be rejected (the header's length
        // field no longer matches the bytes present).
        for _ in 0..40 {
            let keep = rng.below_usize(frame.len());
            must_err("decode/truncation", GENERAL_CAP, || messages::decode(&frame[..keep]));
        }

        // Length-field inflation: over the frame cap fails the cap check;
        // under it fails the bytes-present check. Neither may allocate.
        for _ in 0..40 {
            let mut m = frame.clone();
            let hostile = match rng.below(3) {
                0 => rng.next_u64(),
                1 => MAX_FRAME_BYTES + 1 + rng.below(1 << 30),
                _ => payload_len as u64 + 1 + rng.below(1 << 20),
            };
            m[6..14].copy_from_slice(&hostile.to_le_bytes());
            must_err("decode/len-inflation", GENERAL_CAP, || messages::decode(&m));
        }

        // Tag corruption outside the valid dense 1..=15 range must be
        // rejected. A flip onto a *different valid* tag may legally decode
        // if payload shapes coincide, so in-range foreign tags only get the
        // no-panic / bounded-allocation guarantee.
        for hostile_tag in [0u8, 16, 100, 255] {
            let mut m = frame.clone();
            m[5] = hostile_tag;
            must_err("decode/bad-tag", GENERAL_CAP, || messages::decode(&m));
        }
        for _ in 0..8 {
            let mut m = frame.clone();
            m[5] = rng.below(16) as u8;
            guarded("decode/foreign-tag", GENERAL_CAP, || messages::decode(&m));
        }

        // Magic and version corruption must be rejected.
        for off in [0usize, 1, 2, 3, 4] {
            let mut m = frame.clone();
            m[off] ^= 0x5A;
            must_err("decode/bad-magic-or-version", GENERAL_CAP, || messages::decode(&m));
        }

        // Arbitrary single-bit flips: no panic, no oversized allocation.
        // Flips in the payload may legally still decode (e.g. an f32 bit).
        for _ in 0..200 {
            let mut m = frame.clone();
            let off = rng.below_usize(m.len());
            m[off] ^= 1 << rng.below(8);
            guarded("decode/byte-flip", GENERAL_CAP, || messages::decode(&m));
        }

        // The streaming entry point (`read_msg`) may legitimately allocate
        // the claimed payload once the claim passes the frame cap — but
        // never more than MAX_FRAME_BYTES, and an over-cap claim must fail
        // before any allocation of that size.
        let stream_cap = MAX_FRAME_BYTES + (1 << 20);
        for keep in [0, HEADER_BYTES.min(frame.len()), frame.len().saturating_sub(1)] {
            if keep == frame.len() {
                continue;
            }
            let mut cur = std::io::Cursor::new(frame[..keep].to_vec());
            must_err("read_msg/truncation", stream_cap, || messages::read_msg(&mut cur));
        }
        {
            // Claim just over the bytes present but far under the cap:
            // allocates the claim, then fails reading the payload.
            let mut m = frame.clone();
            m[6..14].copy_from_slice(&(payload_len as u64 + 7).to_le_bytes());
            let mut cur = std::io::Cursor::new(m);
            must_err("read_msg/short-claim", stream_cap, || messages::read_msg(&mut cur));
        }
        {
            // Claim over the frame cap: must fail in the cap check, i.e.
            // BEFORE the 256 MiB payload buffer would be allocated.
            let mut m = frame.clone();
            m[6..14].copy_from_slice(&(MAX_FRAME_BYTES + 1).to_le_bytes());
            let mut cur = std::io::Cursor::new(m);
            must_err("read_msg/over-cap-claim", GENERAL_CAP, || messages::read_msg(&mut cur));
        }
    }

    // A self-consistent frame whose payload claims a matrix far larger than
    // the payload itself: the element cap / remaining-bytes checks must
    // reject it before the ~4 TB allocation the dims imply.
    let mut body = Vec::new();
    body.extend_from_slice(&7u64.to_le_bytes()); // step
    body.extend_from_slice(&1u32.to_le_bytes()); // one matrix
    body.extend_from_slice(&(1u32 << 20).to_le_bytes()); // rows
    body.extend_from_slice(&(1u32 << 20).to_le_bytes()); // cols
    let mut frame = Vec::new();
    frame.extend_from_slice(messages::WIRE_MAGIC);
    frame.push(messages::WIRE_VERSION);
    frame.push(3); // GroupState
    frame.extend_from_slice(&(body.len() as u64).to_le_bytes());
    frame.extend_from_slice(&body);
    must_err("decode/hostile-mat-dims", GENERAL_CAP, || messages::decode(&frame));
}

// ---------------------------------------------------------------------------
// Surface 2: checkpoint and shard files (shared 8-byte-magic + u64-header
// layout, so one mutation driver covers both).
// ---------------------------------------------------------------------------

fn fuzz_file<L, T>(label: &str, rng: &mut Rng, valid: &[u8], path: &std::path::Path, load: L)
where
    L: Fn(&std::path::Path) -> sumo::Result<T>,
{
    // Strict truncations: some tensor (or the header) is now missing bytes.
    for _ in 0..30 {
        let keep = rng.below_usize(valid.len());
        std::fs::write(path, &valid[..keep]).unwrap();
        must_err(label, GENERAL_CAP, || load(path));
    }

    // Header-length inflation: over the 16 MiB cap must fail the cap check;
    // moderate inflation must fail parsing/reading without a panic.
    for hostile in [u64::MAX, (16 << 20) + 1] {
        let mut m = valid.to_vec();
        m[8..16].copy_from_slice(&hostile.to_le_bytes());
        std::fs::write(path, &m).unwrap();
        must_err(label, GENERAL_CAP, || load(path));
    }
    let hlen = u64::from_le_bytes(valid[8..16].try_into().unwrap());
    for _ in 0..10 {
        let mut m = valid.to_vec();
        m[8..16].copy_from_slice(&(hlen + 1 + rng.below(64)).to_le_bytes());
        std::fs::write(path, &m).unwrap();
        guarded(label, GENERAL_CAP, || load(path));
    }

    // Magic corruption.
    for off in [0usize, 1, 2, 3, 4, 5, 6, 7] {
        let mut m = valid.to_vec();
        m[off] ^= 0x5A;
        std::fs::write(path, &m).unwrap();
        must_err(label, GENERAL_CAP, || load(path));
    }

    // Random single-bit flips anywhere in the file: no panic, bounded
    // allocation; flips in tensor payload bytes may legally still load.
    for _ in 0..150 {
        let mut m = valid.to_vec();
        let off = rng.below_usize(m.len());
        m[off] ^= 1 << rng.below(8);
        std::fs::write(path, &m).unwrap();
        guarded(label, GENERAL_CAP, || load(path));
    }
}

fn fuzz_checkpoint(rng: &mut Rng, dir: &std::path::Path) {
    let cfg = ModelCfg::preset("nano").unwrap();
    let store = ParamStore {
        cfg: cfg.clone(),
        tensors: vec![
            ("a".to_string(), Mat::randn(4, 3, 1.0, rng)),
            ("b".to_string(), Mat::randn(2, 5, 1.0, rng)),
        ],
    };
    let path = dir.join("fuzz.ckpt");
    checkpoint::save(&store, 5, &path).unwrap();
    let valid = std::fs::read(&path).unwrap();
    fuzz_file("checkpoint", rng, &valid, &path, |p| checkpoint::load(p).map(|_| ()));

    // A header that *claims* a ~40 GB tensor over a tiny payload: the claim
    // must die against the file's actual length, before any allocation.
    let cfg_json = cfg.to_json().dump();
    let tensors = r#"[{"cols":99999,"name":"w","rows":99999}]"#;
    let header = format!("{{\"cfg\":{cfg_json},\"step\":1,\"tensors\":{tensors}}}");
    let mut hostile = Vec::new();
    hostile.extend_from_slice(b"SUMOCKP1");
    hostile.extend_from_slice(&(header.len() as u64).to_le_bytes());
    hostile.extend_from_slice(header.as_bytes());
    hostile.extend_from_slice(&[0u8; 8]);
    std::fs::write(&path, &hostile).unwrap();
    MAX_ALLOC.store(0, Ordering::SeqCst);
    let err = match checkpoint::load(&path) {
        Ok(_) => panic!("checkpoint claiming a 40 GB tensor loaded Ok"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("remain in the file"), "unexpected rejection: {err}");
    let peak = MAX_ALLOC.load(Ordering::SeqCst);
    assert!(peak <= GENERAL_CAP, "hostile header allocated {peak} bytes");
}

fn fuzz_shard(rng: &mut Rng, dir: &std::path::Path) {
    let layers = vec![
        messages::LayerSpec { name: "l0.wq".into(), rows: 4, cols: 4, projected: true },
        messages::LayerSpec { name: "l0.norm".into(), rows: 1, cols: 4, projected: false },
    ];
    let weights: Vec<Mat> = layers.iter().map(|l| Mat::randn(l.rows, l.cols, 1.0, rng)).collect();
    let meta = ShardMeta {
        tag: "nano".into(),
        worker_id: 0,
        n_workers: 1,
        step: 3,
        group_start: 0,
        group_end: 2,
        layers,
        ckpt_base: 0,
        owners: vec![(0, 0, 2)],
    };
    let path = dir.join("fuzz.shard");
    shard::save(&meta, &weights, &path).unwrap();
    let valid = std::fs::read(&path).unwrap();
    fuzz_file("shard", rng, &valid, &path, |p| shard::load(p).map(|_| ()));
}

// ---------------------------------------------------------------------------
// Surface 3: config JSON (`Json::parse` + typed `from_json`).
// ---------------------------------------------------------------------------

fn fuzz_config_json(rng: &mut Rng) {
    let texts = [
        ClusterCfg::default().to_json().dump(),
        OptimCfg::new(OptimKind::Sumo).with_lr(0.01).with_rank(8).to_json().dump(),
    ];
    for text in &texts {
        // Any strict prefix of a compact JSON object is unbalanced: the
        // closing brace is the last byte, so every truncation must fail.
        for _ in 0..40 {
            let keep = rng.below_usize(text.len());
            if !text.is_char_boundary(keep) {
                continue;
            }
            let prefix = text[..keep].to_string();
            must_err("json/truncation", GENERAL_CAP, || {
                Json::parse(&prefix).map_err(|e| anyhow::anyhow!("{e}"))
            });
        }
        // Byte flips (kept ASCII so the mutant stays a valid `str`):
        // parsing may fail or succeed, typed extraction may yield `None` —
        // but nothing may panic.
        for _ in 0..200 {
            let mut bytes = text.clone().into_bytes();
            let off = rng.below_usize(bytes.len());
            bytes[off] = (bytes[off] ^ (1 << rng.below(7))) & 0x7F;
            let Ok(mutant) = String::from_utf8(bytes) else { continue };
            guarded("json/byte-flip", GENERAL_CAP, || {
                if let Ok(j) = Json::parse(&mutant) {
                    let _ = ClusterCfg::from_json(&j);
                    let _ = OptimCfg::from_json(&j);
                }
                Ok(())
            });
        }
        // Number inflation: absurd numeric magnitudes must saturate through
        // the typed accessors, not panic.
        let inflated = text.replace(":2", ":999999999999999999999999");
        guarded("json/number-inflation", GENERAL_CAP, || {
            if let Ok(j) = Json::parse(&inflated) {
                let _ = ClusterCfg::from_json(&j);
                let _ = OptimCfg::from_json(&j);
            }
            Ok(())
        });
    }
}

// ---------------------------------------------------------------------------
// Surface 4: chaos fault specs (`ChaosSpec::parse`) — CLI today, but the
// same hostile-input discipline as every other decoder.
// ---------------------------------------------------------------------------

fn fuzz_chaos_spec(rng: &mut Rng) {
    let valid = concat!(
        r#"[{"kind":"kill","step":5},{"kind":"leave","step":"seeded"},"#,
        r#"{"kind":"stall","ms":40},{"kind":"drop","frame":2},"#,
        r#"{"kind":"truncate","frame":9},{"kind":"delay","frame":1,"ms":10}]"#
    );
    ChaosSpec::parse(valid).expect("fixture spec must parse");

    // Compact JSON array: the closing bracket is the last byte, so every
    // strict truncation must be rejected.
    for _ in 0..40 {
        let keep = rng.below_usize(valid.len());
        must_err("chaos/truncation", GENERAL_CAP, || {
            ChaosSpec::parse(&valid[..keep]).map(|_| ())
        });
    }

    // ASCII byte flips: parsing may fail, or legally succeed (a digit
    // flip), but must never panic or over-allocate.
    for _ in 0..200 {
        let mut bytes = valid.as_bytes().to_vec();
        let off = rng.below_usize(bytes.len());
        bytes[off] = (bytes[off] ^ (1 << rng.below(7))) & 0x7F;
        let Ok(mutant) = String::from_utf8(bytes) else { continue };
        guarded("chaos/byte-flip", GENERAL_CAP, || {
            let _ = ChaosSpec::parse(&mutant);
            Ok(())
        });
    }

    // The fault-count cap: one fault over MAX_FAULTS must be rejected.
    let mut big = String::from("[");
    for i in 0..=MAX_FAULTS {
        if i > 0 {
            big.push(',');
        }
        big.push_str(r#"{"kind":"kill","step":1}"#);
    }
    big.push(']');
    must_err("chaos/over-cap", GENERAL_CAP, || ChaosSpec::parse(&big).map(|_| ()));
}

// ---------------------------------------------------------------------------
// Surface 5: compressed gradient frames (`cluster::codec::decode_mats`).
// The wire v4 payload inside `Msg::Grads`/`Msg::ReducedGrads`: codec
// envelope, per-mat dims, RLE plane streams, quantization scales.
// ---------------------------------------------------------------------------

fn fuzz_grads_codec(rng: &mut Rng) {
    let mats = vec![
        Mat::randn(8, 5, 1e-3, rng),
        Mat::from_vec(1, 6, vec![0.0; 6]), // zero pages in the lossless path
        Mat::from_vec(0, 0, vec![]),
    ];
    for codec in [GradCodec::Raw, GradCodec::Lossless, GradCodec::Q8Det] {
        let valid = encode_mats(codec, &mats);
        decode_mats(codec, &valid).expect("fixture payload must decode");

        // Every strict truncation is rejected: dims without bodies,
        // RLE streams cut mid-run, missing plane sections.
        for _ in 0..60 {
            let keep = rng.below_usize(valid.len());
            must_err("grads-codec/truncation", GENERAL_CAP, || {
                decode_mats(codec, &valid[..keep])
            });
        }

        // Codec-id corruption: any id but the negotiated one — valid
        // foreign ids and garbage alike — errs cleanly before mat decode.
        for hostile_id in [0u8, 1, 2, 3, 77, 255] {
            if hostile_id == codec.id() {
                continue;
            }
            let mut m = valid.clone();
            m[0] = hostile_id;
            must_err("grads-codec/id-corruption", GENERAL_CAP, || decode_mats(codec, &m));
        }

        // Inflated mat-count claim dies at the MAX_MATS cap, before the
        // mat vector is sized by it.
        {
            let mut m = valid.clone();
            m[1..5].copy_from_slice(&u32::MAX.to_le_bytes());
            must_err("grads-codec/count-inflation", GENERAL_CAP, || decode_mats(codec, &m));
        }

        // Inflated dims on the first mat body (rows at offset 5): the
        // element-cap check fires before any allocation sized by the claim.
        {
            let mut m = valid.clone();
            m[5..9].copy_from_slice(&u32::MAX.to_le_bytes());
            must_err("grads-codec/dim-inflation", GENERAL_CAP, || decode_mats(codec, &m));
        }

        // Arbitrary single-bit flips: a payload-byte flip may legally still
        // decode; nothing may panic or allocate past the cap.
        for _ in 0..300 {
            let mut m = valid.clone();
            let off = rng.below_usize(m.len());
            m[off] ^= 1 << rng.below(8);
            guarded("grads-codec/byte-flip", GENERAL_CAP, || decode_mats(codec, &m));
        }
    }

    // Hand-built lossless mutant: an RLE section claiming a huge encoded
    // length over a short payload must die against the frame cap / bytes
    // present, never allocate the claim.
    let mut m = vec![1u8]; // lossless id
    m.extend_from_slice(&1u32.to_le_bytes()); // one mat
    m.extend_from_slice(&2u32.to_le_bytes()); // rows
    m.extend_from_slice(&2u32.to_le_bytes()); // cols
    m.push(1); // PLANE_RLE
    m.extend_from_slice(&u32::MAX.to_le_bytes()); // hostile encoded length
    must_err("grads-codec/hostile-rle-len", GENERAL_CAP, || {
        decode_mats(GradCodec::Lossless, &m)
    });

    // Hand-built q8 mutant: a NaN wire scale is corruption (the encoder
    // can never produce one) and must be rejected.
    let mut m = vec![2u8];
    m.extend_from_slice(&1u32.to_le_bytes());
    m.extend_from_slice(&1u32.to_le_bytes());
    m.extend_from_slice(&2u32.to_le_bytes());
    m.extend_from_slice(&f32::NAN.to_le_bytes());
    m.extend_from_slice(&[1, 2]);
    must_err("grads-codec/nan-scale", GENERAL_CAP, || decode_mats(GradCodec::Q8Det, &m));
}

// ---------------------------------------------------------------------------

#[test]
fn hostile_inputs_never_panic_or_overallocate() {
    let mut rng = Rng::new(0xF077_2E5D);
    let dir = scratch_dir();
    fuzz_wire(&mut rng);
    fuzz_checkpoint(&mut rng, &dir);
    fuzz_shard(&mut rng, &dir);
    fuzz_config_json(&mut rng);
    fuzz_chaos_spec(&mut rng);
    fuzz_grads_codec(&mut rng);
    std::fs::remove_dir_all(&dir).ok();
}
