//! Adaptive rank growth + cost-aware refresh scheduling, end to end.
//!
//! Three properties are load bearing:
//!
//! * **Pinned band ⇒ bitwise-fixed run** — with the adaptive machinery
//!   enabled but the rank band pinned to `[r, r]` (default cadence), every
//!   step must be bitwise identical to the plain fixed-(r, K) optimizer:
//!   measurement must not perturb the basis RNG or any optimizer state.
//! * **Rank events are sound** — a grow step transports the moment into the
//!   wider subspace (no NaNs, back-projection error shrinks) and the
//!   optimizer keeps optimizing across the boundary.
//! * **Determinism across pool sizes** — the three-phase grouped dispatch
//!   stays bitwise identical to the serial loop at pool sizes {1, 2, 8}
//!   even when steps cross rank-change boundaries (groups and scratch are
//!   rebuilt mid-run).

use sumo::config::{OptimCfg, OptimKind};
use sumo::linalg::{matmul, subspace_residual, Mat};
use sumo::optim;
use sumo::optim::subspace::{AdaptiveSpec, RankBand, SubspaceState};
use sumo::util::threadpool::ThreadPool;
use sumo::util::Rng;

/// Mixed model: dense norm layer + both projection orientations + square,
/// with repeated shapes so the grouped dispatch gets real multi-member
/// shape classes.
fn layer_shapes() -> (Vec<(usize, usize)>, Vec<bool>) {
    let mut shapes: Vec<(usize, usize)> = vec![(1, 32)];
    let mut projected = vec![false];
    for _ in 0..3 {
        shapes.push((64, 32));
        projected.push(true);
    }
    for _ in 0..2 {
        shapes.push((32, 64));
        projected.push(true);
    }
    shapes.push((48, 48));
    projected.push(true);
    (shapes, projected)
}

/// Run `steps` serial optimizer steps from a fixed seed; returns weights.
fn run_serial(
    cfg: &OptimCfg,
    shapes: &[(usize, usize)],
    projected: &[bool],
    steps: usize,
) -> Vec<Mat> {
    let mut opt = optim::build(cfg, shapes, projected, 42);
    let mut wrng = Rng::new(7);
    let mut weights: Vec<Mat> = shapes
        .iter()
        .map(|&(m, n)| Mat::randn(m, n, 0.5, &mut wrng))
        .collect();
    let mut grng = Rng::new(8);
    for _ in 0..steps {
        let grads: Vec<Mat> = shapes
            .iter()
            .map(|&(m, n)| Mat::randn(m, n, 1.0, &mut grng))
            .collect();
        for (i, (w, g)) in weights.iter_mut().zip(&grads).enumerate() {
            opt.step(i, w, g, 1.0);
        }
        opt.end_step();
    }
    weights
}

#[test]
fn pinned_band_matches_fixed_run_bitwise() {
    // adaptive_rank on with r_min == r_max == rank and the default cadence:
    // the residual is measured at every refresh, but nothing may move — so
    // the run must be bitwise identical to the plain fixed-(r, K) one.
    let (shapes, projected) = layer_shapes();
    for kind in [OptimKind::Sumo, OptimKind::SumoNs5, OptimKind::GaLore] {
        let fixed = OptimCfg::new(kind).with_lr(0.02).with_rank(4).with_update_freq(3);
        let pinned = fixed.clone().with_adaptive_rank(4, 4);
        let w_fixed = run_serial(&fixed, &shapes, &projected, 10);
        let w_pinned = run_serial(&pinned, &shapes, &projected, 10);
        for (i, (a, b)) in w_fixed.iter().zip(&w_pinned).enumerate() {
            assert!(a.is_finite(), "{kind:?} layer {i} not finite");
            assert_eq!(
                a.max_diff(b),
                0.0,
                "{kind:?} layer {i}: pinned-band adaptive run diverged from fixed run"
            );
        }
    }
}

#[test]
fn pinned_cadence_matches_fixed_run_bitwise() {
    // adaptive_freq pinned to [K, K] (with K above the amortized-cost
    // floor): the interval is re-derived every refresh but must never move.
    let (shapes, projected) = layer_shapes();
    let fixed = OptimCfg::new(OptimKind::Sumo).with_lr(0.02).with_rank(4).with_update_freq(4);
    let mut pinned = fixed.clone().with_adaptive_rank(4, 4).with_adaptive_freq();
    pinned.freq_min = 4;
    pinned.freq_max = 4;
    pinned.refresh_budget = 10.0; // cost floor = 1: the [4, 4] clamp rules
    let w_fixed = run_serial(&fixed, &shapes, &projected, 12);
    let w_pinned = run_serial(&pinned, &shapes, &projected, 12);
    for (a, b) in w_fixed.iter().zip(&w_pinned) {
        assert_eq!(a.max_diff(b), 0.0, "pinned-cadence run diverged from fixed run");
    }
}

#[test]
fn grow_event_transports_moment_and_shrinks_residual() {
    // Rank-8 gradient, rank-4 basis, band [4, 16]: the residual trigger
    // must grow the rank, the transported moment must stay finite at the
    // new shape, and the refreshed (wider) basis must capture strictly
    // more of the gradient than the starved one did.
    let mut rng = Rng::new(90);
    let u = Mat::randn(64, 8, 1.0, &mut rng);
    let v = Mat::randn(8, 32, 1.0, &mut rng);
    let g = matmul(&u, &v);
    let spec = AdaptiveSpec {
        residual_lo: 0.001,
        residual_hi: 0.05,
        rank: Some(RankBand {
            r_min: 4,
            r_max: 16,
            step: 4,
        }),
        refresh: None,
    };
    let mut ss = SubspaceState::new(64, 32, 4, 5, Rng::new(91)).with_adaptive(Some(spec));
    ss.refresh(&g, None);
    let before = subspace_residual(&g, ss.q.as_ref().unwrap());
    assert!(before > 0.05, "rank-4 basis must miss rank-8 mass: {before}");
    let moment = Some(ss.project(&g));
    let transported = ss.refresh(&g, moment).unwrap();
    assert_eq!(ss.rank, 8, "grow step of 4 from rank 4");
    assert_eq!(ss.rank_events(), 1);
    assert_eq!(transported.shape(), ss.moment_shape(64, 32));
    assert!(transported.is_finite(), "transport produced non-finite moment");
    let after = subspace_residual(&g, ss.q.as_ref().unwrap());
    assert!(
        after < before,
        "back-projection error must shrink across the grow event: {before} -> {after}"
    );
    assert!(after < 1e-3, "rank-8 basis captures the rank-8 gradient: {after}");
}

#[test]
fn sumo_keeps_optimizing_across_rank_events() {
    // Quadratic descent with an adaptive band wide enough to move: the run
    // must stay finite, trigger at least one rank event, and reduce loss.
    let mut cfg = OptimCfg::new(OptimKind::Sumo)
        .with_lr(0.05)
        .with_rank(2)
        .with_update_freq(5)
        .with_adaptive_rank(2, 12)
        .with_residual_band(0.01, 0.05);
    cfg.rank_step = 2;
    let mut opt = optim::build(&cfg, &[(32, 16)], &[true], 1);
    let mut rng = Rng::new(11);
    let target = Mat::randn(32, 16, 1.0, &mut rng);
    let mut w = Mat::zeros(32, 16);
    let l0 = target.sumsq();
    for _ in 0..200 {
        let mut g = w.clone();
        g.axpy(-1.0, &target);
        opt.step(0, &mut w, &g, 1.0);
        opt.end_step();
    }
    assert!(w.is_finite());
    let sumo_ref = opt.as_sumo().expect("built a Sumo");
    assert!(sumo_ref.rank_events() > 0, "full-rank residual must trigger growth");
    assert!(sumo_ref.layer_rank(0).unwrap() > 2, "rank must have grown");
    assert!(sumo_ref.refresh_flops_spent() > 0);
    let mut diff = w.clone();
    diff.axpy(-1.0, &target);
    assert!(diff.sumsq() < 0.35 * l0, "loss {l0} -> {}", diff.sumsq());
}

#[test]
fn galore_survives_rank_events() {
    // GaLore inherits the adaptive subspace; a rank event resets V (no
    // transport exists for it) — the run must stay finite and converge.
    let mut cfg = OptimCfg::new(OptimKind::GaLore)
        .with_lr(0.05)
        .with_rank(2)
        .with_update_freq(5)
        .with_adaptive_rank(2, 8)
        .with_residual_band(0.01, 0.05);
    cfg.rank_step = 2;
    let mut opt = optim::build(&cfg, &[(32, 16)], &[true], 3);
    let mut rng = Rng::new(13);
    let u = Mat::randn(32, 4, 1.0, &mut rng);
    let vt = Mat::randn(4, 16, 1.0, &mut rng);
    let target = matmul(&u, &vt);
    let mut w = Mat::zeros(32, 16);
    for _ in 0..300 {
        let mut g = w.clone();
        g.axpy(-1.0, &target);
        opt.step(0, &mut w, &g, 1.0);
        opt.end_step();
    }
    assert!(w.is_finite());
    // The moment spectrum length is the live rank: growth must have fired.
    let live_rank = opt.as_galore().unwrap().moment_spectrum(0).unwrap().len();
    assert!(live_rank > 2, "galore rank must have grown: {live_rank}");
    assert!(
        w.max_diff(&target) < 0.3 * target.max_abs(),
        "diff={}",
        w.max_diff(&target)
    );
}

#[test]
fn pool_sweep_bitwise_across_rank_events() {
    // Adaptive run with frequent refreshes and a wide band: rank events hit
    // mid-run, forcing group/scratch rebuilds in the three-phase dispatch.
    // Every pool size must stay bitwise identical to the serial loop.
    let (shapes, projected) = layer_shapes();
    let mut cfg = OptimCfg::new(OptimKind::Sumo)
        .with_lr(0.02)
        .with_rank(2)
        .with_update_freq(2)
        .with_adaptive_rank(2, 12)
        .with_residual_band(0.01, 0.05);
    cfg.rank_step = 4;
    cfg.weight_decay = 0.05;
    let w_serial = run_serial(&cfg, &shapes, &projected, 9);
    for workers in [1usize, 2, 8] {
        let pool = ThreadPool::new(workers);
        let mut par = optim::build(&cfg, &shapes, &projected, 42);
        let mut wrng = Rng::new(7);
        let mut w_par: Vec<Mat> = shapes
            .iter()
            .map(|&(m, n)| Mat::randn(m, n, 0.5, &mut wrng))
            .collect();
        let mut grng = Rng::new(8);
        for _ in 0..9 {
            let grads: Vec<Mat> = shapes
                .iter()
                .map(|&(m, n)| Mat::randn(m, n, 1.0, &mut grng))
                .collect();
            let mut refs: Vec<&mut Mat> = w_par.iter_mut().collect();
            par.step_parallel(&pool, &mut refs, &grads, 1.0);
            par.end_step();
        }
        let sumo_ref = par.as_sumo().expect("built a Sumo");
        assert!(sumo_ref.rank_events() > 0, "run must cross a rank boundary");
        for (i, (a, b)) in w_serial.iter().zip(&w_par).enumerate() {
            assert!(a.is_finite(), "layer {i} not finite");
            assert_eq!(
                a.max_diff(b),
                0.0,
                "pool={workers} layer {i}: threaded adaptive step diverged from serial"
            );
        }
    }
}

#[test]
fn adaptive_cadence_stretches_on_lowrank_gradients() {
    // A gradient stream of fixed low rank collapses the residual signal, so
    // the cost-aware schedule must stretch K — fewer refreshes than the
    // fixed-cadence run over the same horizon.
    let mut rng = Rng::new(21);
    let u = Mat::randn(48, 2, 1.0, &mut rng);
    let v = Mat::randn(2, 24, 1.0, &mut rng);
    let g = matmul(&u, &v);
    let run = |adaptive: bool| -> usize {
        let mut cfg = OptimCfg::new(OptimKind::Sumo).with_lr(0.01).with_rank(4).with_update_freq(4);
        if adaptive {
            cfg = cfg.with_adaptive_freq();
        }
        let mut opt = optim::build(&cfg, &[(48, 24)], &[true], 5);
        let mut w = Mat::zeros(48, 24);
        for _ in 0..64 {
            opt.step(0, &mut w, &g, 1.0);
            opt.end_step();
        }
        opt.as_sumo().unwrap().refresh_flops_spent() as usize
    };
    let fixed = run(false);
    let adaptive = run(true);
    assert!(
        adaptive < fixed,
        "stretched cadence must spend fewer refresh FLOPs: {adaptive} vs {fixed}"
    );
}
