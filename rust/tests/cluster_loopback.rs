//! Cluster wire-path integration tests: a real coordinator + real workers
//! over localhost TCP, checked bitwise against the single-process
//! reference, plus the failure paths (hostile frames, inconsistent resume,
//! kill-all) that must error cleanly instead of hanging.
//!
//! The `chaos_*` tests drive the fault-tolerance machinery with scripted
//! faults: killed and stalled workers, clean leaves, elastic joiners, and
//! total cluster loss — every surviving run must stay bitwise identical to
//! the failure-free reference.

use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use sumo::cluster::chaos::ChaosSpec;
use sumo::cluster::codec::GradCodec;
use sumo::cluster::messages::{
    encode, read_msg, write_msg, Msg, HEADER_BYTES, TASK_SUPPORT_ALL, WIRE_MAGIC, WIRE_VERSION,
};
use sumo::cluster::worker::{WorkerCfg, WorkerReport};
use sumo::cluster::{coordinator, local, task, weights_fingerprint, RunOutcome};
use sumo::config::{ClusterCfg, Schedule};

fn test_cfg(name: &str, workers: usize, steps: usize) -> ClusterCfg {
    ClusterCfg {
        workers,
        steps,
        sigma: 0.01,
        heartbeat_every: 2,
        io_timeout_ms: 4000,
        join_timeout_ms: 10_000,
        ckpt_dir: std::env::temp_dir()
            .join(format!("sumo_cluster_{name}"))
            .to_string_lossy()
            .into_owned(),
        ..ClusterCfg::default()
    }
}

/// Bind port 0, run the coordinator on a thread, and hand the real address
/// to the caller so workers can be pointed at it.
fn spawn_coordinator(
    cfg: ClusterCfg,
) -> (String, std::thread::JoinHandle<sumo::Result<RunOutcome>>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || coordinator::run_on(&cfg, listener));
    (addr, handle)
}

fn spawn_worker(
    id: u32,
    addr: &str,
) -> std::thread::JoinHandle<sumo::Result<WorkerReport>> {
    let cfg = WorkerCfg::new(id, addr);
    std::thread::spawn(move || sumo::cluster::worker::run(&cfg))
}

fn spawn_chaos_worker(
    id: u32,
    addr: &str,
    spec: &str,
) -> std::thread::JoinHandle<sumo::Result<WorkerReport>> {
    let mut cfg = WorkerCfg::new(id, addr);
    cfg.chaos = ChaosSpec::parse(spec).unwrap();
    std::thread::spawn(move || sumo::cluster::worker::run(&cfg))
}

/// A worker speaking a specific gradient codec (and optionally a chaos
/// script) — the wire v4 conformance tests drive every codec through the
/// same spawn path.
fn spawn_codec_worker(
    id: u32,
    addr: &str,
    codec: &str,
    chaos: Option<&str>,
) -> std::thread::JoinHandle<sumo::Result<WorkerReport>> {
    let mut cfg = WorkerCfg::new(id, addr);
    cfg.grad_codec = GradCodec::parse(codec).unwrap();
    if let Some(spec) = chaos {
        cfg.chaos = ChaosSpec::parse(spec).unwrap();
    }
    std::thread::spawn(move || sumo::cluster::worker::run(&cfg))
}

#[test]
fn loopback_run_is_bitwise_identical_to_single_process() {
    let mut cfg = test_cfg("loopback", 2, 8);
    cfg.ckpt_every = 3; // exercise the mid-run checkpoint barrier too
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();

    let (addr, coord) = spawn_coordinator(cfg.clone());
    let w0 = spawn_worker(0, &addr);
    let w1 = spawn_worker(1, &addr);
    let outcome = coord.join().unwrap().expect("coordinator failed");
    let r0 = w0.join().unwrap().expect("worker 0 failed");
    let r1 = w1.join().unwrap().expect("worker 1 failed");

    let reference = local::run_local(&cfg).unwrap();
    assert_eq!(outcome.start_step, 0);
    assert_eq!(outcome.final_step, 8);
    assert_eq!(
        weights_fingerprint(&outcome.weights),
        weights_fingerprint(&reference.weights),
        "cluster weights must be bitwise identical to the single-process run"
    );
    assert_eq!(outcome.final_loss, reference.final_loss);
    // Every worker's replicated weights match the coordinator's gather.
    assert_eq!(r0.weights_fnv, weights_fingerprint(&outcome.weights));
    assert_eq!(r1.weights_fnv, r0.weights_fnv);
    assert_eq!((r0.steps_run, r1.steps_run), (8, 8));
    assert_eq!(r0.shutdown_reason, "done");
    // Both shard checkpoints exist (the final barrier always writes them).
    for id in 0..2 {
        assert!(sumo::cluster::shard::shard_path(&cfg.ckpt_dir, id, 2).exists());
    }
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();
}

#[test]
fn resume_continues_from_shard_files_and_rejects_mismatched_steps() {
    let mut cfg = test_cfg("resume", 2, 6);
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();

    // Session 1: fresh run, leaves shard files at step 6.
    let (addr, coord) = spawn_coordinator(cfg.clone());
    let (w0, w1) = (spawn_worker(0, &addr), spawn_worker(1, &addr));
    let first = coord.join().unwrap().unwrap();
    w0.join().unwrap().unwrap();
    w1.join().unwrap().unwrap();
    assert_eq!(first.final_step, 6);

    // Session 2: resume + 4 more steps picks up at step 6.
    cfg.resume = true;
    cfg.steps = 4;
    let (addr, coord) = spawn_coordinator(cfg.clone());
    let (w0, w1) = (spawn_worker(0, &addr), spawn_worker(1, &addr));
    let second = coord.join().unwrap().unwrap();
    let r0 = w0.join().unwrap().unwrap();
    w1.join().unwrap().unwrap();
    assert_eq!(second.start_step, 6);
    assert_eq!(second.final_step, 10);
    assert_eq!(r0.final_step, 10);
    assert_ne!(
        weights_fingerprint(&second.weights),
        weights_fingerprint(&first.weights),
        "resumed session must make progress"
    );

    // Session 3: worker 1 resumes from an empty directory — its offer (step
    // 0) disagrees with worker 0's (step 10) and the coordinator must fail
    // with a clean reconciliation error, not mix the steps.
    let empty = std::env::temp_dir().join("sumo_cluster_resume_empty");
    std::fs::remove_dir_all(&empty).ok();
    let (addr, coord) = spawn_coordinator(cfg.clone());
    let w0 = spawn_worker(0, &addr);
    let mut wc1 = WorkerCfg::new(1, &addr);
    wc1.ckpt_dir = Some(empty.to_string_lossy().into_owned());
    let w1 = std::thread::spawn(move || sumo::cluster::worker::run(&wc1));
    let err = coord.join().unwrap().unwrap_err().to_string();
    assert!(err.contains("inconsistent shard checkpoints"), "got: {err}");
    // Both workers are released by the abort broadcast — no hang.
    let r0 = w0.join().unwrap().unwrap();
    assert!(r0.shutdown_reason.contains("aborted"), "got: {}", r0.shutdown_reason);
    w1.join().unwrap().unwrap();
    std::fs::remove_dir_all(&empty).ok();
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();
}

/// `--task lm` over real sockets: the transformer gradient path through
/// the wire must land on exactly the same bits as (a) the single-process
/// reference runner and (b) the in-process `Trainer` on the native engine.
#[test]
fn lm_loopback_matches_local_runner_and_native_trainer() {
    let mut cfg = test_cfg("lm_loopback", 2, 3);
    cfg.task = "lm".to_string();
    cfg.train.batch = 2;
    cfg.train.eval_batches = 2;
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();

    let (addr, coord) = spawn_coordinator(cfg.clone());
    let (w0, w1) = (spawn_worker(0, &addr), spawn_worker(1, &addr));
    let outcome = coord.join().unwrap().expect("coordinator failed");
    let r0 = w0.join().unwrap().expect("worker 0 failed");
    let r1 = w1.join().unwrap().expect("worker 1 failed");

    let fnv = weights_fingerprint(&outcome.weights);
    assert_eq!(outcome.final_step, 3);
    assert_eq!(r0.weights_fnv, fnv, "worker 0 replica diverged");
    assert_eq!(r1.weights_fnv, fnv, "worker 1 replica diverged");

    let reference = local::run_local(&cfg).unwrap();
    assert_eq!(
        fnv,
        weights_fingerprint(&reference.weights),
        "cluster LM weights must be bitwise identical to the local runner"
    );
    assert_eq!(outcome.final_loss, reference.final_loss);

    // The Trainer path: same model/seed/steps/batch/schedule, dp_workers ==
    // cluster workers — one training engine, three entry points, same bits.
    let model = sumo::config::ModelCfg::preset(&cfg.preset).unwrap();
    let mut tcfg = cfg.train.clone();
    tcfg.steps = cfg.steps;
    tcfg.seed = cfg.seed;
    tcfg.dp_workers = cfg.workers;
    let native = sumo::train::Trainer::new(tcfg)
        .pretrain_native(&model, &cfg.optim, None)
        .unwrap();
    assert_eq!(
        native.weights_fnv, fnv,
        "single-process Trainer must agree bitwise with the cluster"
    );
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();
}

/// LM shard checkpoints resume across sessions exactly like synthetic ones.
#[test]
fn lm_resume_continues_across_sessions() {
    let mut cfg = test_cfg("lm_resume", 2, 3);
    cfg.task = "lm".to_string();
    cfg.train.batch = 2;
    cfg.train.eval_batches = 2;
    // A constant schedule keeps step semantics identical across sessions
    // (cosine spans would differ between a 3-step and a 2-step session).
    cfg.train.schedule = Schedule::Constant;
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();

    let (addr, coord) = spawn_coordinator(cfg.clone());
    let (w0, w1) = (spawn_worker(0, &addr), spawn_worker(1, &addr));
    let first = coord.join().unwrap().unwrap();
    w0.join().unwrap().unwrap();
    w1.join().unwrap().unwrap();
    assert_eq!(first.final_step, 3);

    cfg.resume = true;
    cfg.steps = 2;
    let (addr, coord) = spawn_coordinator(cfg.clone());
    let (w0, w1) = (spawn_worker(0, &addr), spawn_worker(1, &addr));
    let second = coord.join().unwrap().unwrap();
    let r0 = w0.join().unwrap().unwrap();
    w1.join().unwrap().unwrap();
    assert_eq!(second.start_step, 3);
    assert_eq!(second.final_step, 5);
    assert_eq!(r0.final_step, 5);
    assert_ne!(
        second.fingerprint(),
        first.fingerprint(),
        "resumed LM session must make progress"
    );
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();
}

#[test]
fn chaos_silent_worker_is_taken_over_and_the_run_completes() {
    let mut cfg = test_cfg("takeover", 2, 8);
    cfg.io_timeout_ms = 1000; // fast dead-worker detection for the test
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();

    let (addr, coord) = spawn_coordinator(cfg.clone());
    let w0 = spawn_worker(0, &addr);
    // "Zombie" worker 1: speaks the protocol through the handshake, then
    // goes silent mid-run — the shape of a killed/hung process.
    let zaddr = addr.clone();
    let zombie = std::thread::spawn(move || {
        let mut s = TcpStream::connect(&zaddr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        write_msg(
            &mut s,
            &Msg::Hello { worker_id: 1, task_support: TASK_SUPPORT_ALL, codec: 0 },
        )
        .unwrap();
        let a = match read_msg(&mut s).unwrap() {
            Msg::AssignShards(a) => *a,
            m => panic!("expected assignment, got {}", m.name()),
        };
        let group = a.group_start as usize..a.group_end as usize;
        let weights = task::init_weights(a.seed, &a.layers);
        write_msg(
            &mut s,
            &Msg::GroupState { step: 0, mats: weights[group].to_vec() },
        )
        .unwrap();
        match read_msg(&mut s).unwrap() {
            Msg::SyncWeights { .. } => {}
            m => panic!("expected SyncWeights, got {}", m.name()),
        }
        // Silence. Hold the socket open so only the timeout can detect us.
        std::thread::sleep(Duration::from_millis(2500));
    });

    // The survivor recomputes the zombie's shard; the run completes with
    // exactly the bits the failure-free reference produces.
    let outcome = coord.join().unwrap().expect("survivor takeover failed");
    let r0 = w0.join().unwrap().expect("surviving worker failed");
    zombie.join().unwrap();
    let reference = local::run_local(&cfg).unwrap();
    assert_eq!(
        weights_fingerprint(&outcome.weights),
        weights_fingerprint(&reference.weights),
        "takeover weights must stay bitwise identical to the failure-free reference"
    );
    assert_eq!(outcome.final_step, 8);
    assert!(outcome.recovered >= 1, "the zombie's shard was recovered");
    assert_eq!(r0.shutdown_reason, "done");
    assert_eq!(r0.weights_fnv, weights_fingerprint(&outcome.weights));
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();
}

#[test]
fn chaos_killed_worker_mid_run_keeps_weights_bitwise_identical() {
    let cfg = test_cfg("chaos_kill", 2, 8);
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();

    let (addr, coord) = spawn_coordinator(cfg.clone());
    let w0 = spawn_worker(0, &addr);
    let w1 = spawn_chaos_worker(1, &addr, r#"[{"kind":"kill","step":4}]"#);

    let outcome = coord.join().unwrap().expect("takeover after kill failed");
    let r0 = w0.join().unwrap().expect("survivor failed");
    let err = w1.join().unwrap().unwrap_err().to_string();
    assert!(err.contains("chaos: killed at step 4"), "got: {err}");

    let reference = local::run_local(&cfg).unwrap();
    assert_eq!(outcome.final_step, 8);
    assert_eq!(
        weights_fingerprint(&outcome.weights),
        weights_fingerprint(&reference.weights),
        "takeover weights must stay bitwise identical to the failure-free reference"
    );
    assert!(outcome.recovered >= 1);
    assert_eq!(r0.steps_run, 8);
    assert_eq!(r0.shutdown_reason, "done");
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();
}

#[test]
fn chaos_leave_and_kill_degrade_to_a_single_survivor() {
    let cfg = test_cfg("chaos_degrade", 3, 9);
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();

    let (addr, coord) = spawn_coordinator(cfg.clone());
    let w0 = spawn_worker(0, &addr);
    let w1 = spawn_chaos_worker(1, &addr, r#"[{"kind":"leave","step":3}]"#);
    let w2 = spawn_chaos_worker(2, &addr, r#"[{"kind":"kill","step":6}]"#);

    let outcome = coord.join().unwrap().expect("degraded run failed");
    let r0 = w0.join().unwrap().unwrap();
    let r1 = w1.join().unwrap().unwrap();
    let err = w2.join().unwrap().unwrap_err().to_string();
    assert!(err.contains("chaos: killed"), "got: {err}");

    let reference = local::run_local(&cfg).unwrap();
    assert_eq!(
        weights_fingerprint(&outcome.weights),
        weights_fingerprint(&reference.weights),
        "two sequential failures must not change a single bit"
    );
    assert!(outcome.recovered >= 2, "one shard per failure, got {}", outcome.recovered);
    assert_eq!(r1.shutdown_reason, "left");
    assert_eq!(r1.steps_run, 3);
    assert_eq!(r0.shutdown_reason, "done");
    assert_eq!(r0.steps_run, 9);
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();
}

#[test]
fn chaos_stalled_straggler_is_speculated_and_first_result_wins() {
    let mut cfg = test_cfg("chaos_straggler", 2, 10);
    cfg.heartbeat_every = 0;
    cfg.straggler_min_ms = 100; // trigger speculation well inside the stall
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();

    // The stall (1200ms) sits between the straggler deadline (~100ms) and
    // the dead-worker timeout (4000ms): the worker must be speculated
    // around, not declared dead — it catches up and finishes normally.
    let (addr, coord) = spawn_coordinator(cfg.clone());
    let w0 = spawn_worker(0, &addr);
    let w1 = spawn_chaos_worker(1, &addr, r#"[{"kind":"stall","step":5,"ms":1200}]"#);

    let outcome = coord.join().unwrap().expect("straggler round failed");
    let r0 = w0.join().unwrap().unwrap();
    let r1 = w1.join().unwrap().unwrap();

    let reference = local::run_local(&cfg).unwrap();
    assert_eq!(
        weights_fingerprint(&outcome.weights),
        weights_fingerprint(&reference.weights),
        "speculative duplicates must be discarded, not double-counted"
    );
    assert!(outcome.recovered >= 1, "the stalled shard was speculated");
    assert_eq!(r0.shutdown_reason, "done");
    assert_eq!(r1.shutdown_reason, "done", "the straggler survives the round");
    assert_eq!((r0.steps_run, r1.steps_run), (10, 10));
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();
}

#[test]
fn chaos_elastic_joiner_replays_the_prefix_and_matches_bitwise() {
    let mut cfg = test_cfg("chaos_join", 2, 40);
    cfg.heartbeat_every = 0;
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();

    let (addr, coord) = spawn_coordinator(cfg.clone());
    // Both founders stall a little every step so the session is still
    // running when the joiner shows up.
    let w0 = spawn_chaos_worker(0, &addr, r#"[{"kind":"stall","ms":25}]"#);
    let w1 = spawn_chaos_worker(1, &addr, r#"[{"kind":"stall","ms":25}]"#);
    std::thread::sleep(Duration::from_millis(300));
    let w2 = spawn_worker(2, &addr);

    let outcome = coord.join().unwrap().expect("elastic run failed");
    let r0 = w0.join().unwrap().unwrap();
    let r1 = w1.join().unwrap().unwrap();
    let r2 = w2.join().unwrap().unwrap();

    let reference = local::run_local(&cfg).unwrap();
    let fnv = weights_fingerprint(&outcome.weights);
    assert_eq!(
        fnv,
        weights_fingerprint(&reference.weights),
        "an elastic join must not perturb the trajectory"
    );
    assert_eq!(r2.shutdown_reason, "done", "joiner must be admitted mid-run");
    assert!(r2.steps_run > 0 && r2.steps_run < 40, "joined mid-run: {}", r2.steps_run);
    assert_eq!(r2.weights_fnv, fnv, "joiner replica diverged after prefix replay");
    assert_eq!((r0.steps_run, r1.steps_run), (40, 40));
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();
}

#[test]
fn chaos_lm_kill_keeps_transformer_weights_bitwise_identical() {
    let mut cfg = test_cfg("chaos_lm_kill", 2, 3);
    cfg.task = "lm".to_string();
    cfg.train.batch = 2;
    cfg.train.eval_batches = 2;
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();

    let (addr, coord) = spawn_coordinator(cfg.clone());
    let w0 = spawn_worker(0, &addr);
    let w1 = spawn_chaos_worker(1, &addr, r#"[{"kind":"kill","step":1}]"#);

    let outcome = coord.join().unwrap().expect("LM takeover failed");
    let r0 = w0.join().unwrap().unwrap();
    assert!(w1.join().unwrap().is_err(), "the killed worker reports its own death");

    let reference = local::run_local(&cfg).unwrap();
    assert_eq!(
        weights_fingerprint(&outcome.weights),
        weights_fingerprint(&reference.weights),
        "LM takeover must recompute the lost shard's transformer gradients exactly"
    );
    assert!(outcome.recovered >= 1);
    assert_eq!(r0.shutdown_reason, "done");
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();
}

#[test]
fn chaos_total_loss_fails_with_a_clean_error() {
    let cfg = test_cfg("chaos_total", 1, 6);
    let (addr, coord) = spawn_coordinator(cfg);
    let w0 = spawn_chaos_worker(0, &addr, r#"[{"kind":"kill","step":2}]"#);
    let err = coord.join().unwrap().unwrap_err().to_string();
    assert!(err.contains("no surviving workers"), "got: {err}");
    let werr = w0.join().unwrap().unwrap_err().to_string();
    assert!(werr.contains("chaos: killed at step 2"), "got: {werr}");
}

/// Wire v4 acceptance: under every negotiated codec the cluster lands on
/// exactly the bits the single-process reference produces. The reference
/// runs the same codec canonicalization, so the comparison also proves the
/// coordinator and workers agree on what "canonical" means.
#[test]
fn wire_v4_every_codec_matches_local_bitwise() {
    let mut fnvs = Vec::new();
    for codec in ["raw", "lossless", "q8"] {
        let mut cfg = test_cfg(&format!("codec_{codec}"), 2, 6);
        cfg.grad_codec = codec.to_string();
        std::fs::remove_dir_all(&cfg.ckpt_dir).ok();

        let (addr, coord) = spawn_coordinator(cfg.clone());
        let w0 = spawn_codec_worker(0, &addr, codec, None);
        let w1 = spawn_codec_worker(1, &addr, codec, None);
        let outcome = coord.join().unwrap().unwrap_or_else(|e| panic!("{codec}: {e}"));
        let r0 = w0.join().unwrap().expect("worker 0 failed");
        let r1 = w1.join().unwrap().expect("worker 1 failed");

        let reference = local::run_local(&cfg).unwrap();
        let fnv = weights_fingerprint(&outcome.weights);
        assert_eq!(
            fnv,
            weights_fingerprint(&reference.weights),
            "{codec}: cluster weights must be bitwise identical to the local reference"
        );
        assert_eq!(outcome.final_loss, reference.final_loss, "{codec}: loss drift");
        assert_eq!(r0.weights_fnv, fnv, "{codec}: worker 0 replica diverged");
        assert_eq!(r1.weights_fnv, fnv, "{codec}: worker 1 replica diverged");
        std::fs::remove_dir_all(&cfg.ckpt_dir).ok();
        fnvs.push((codec, fnv));
    }
    // Exact codecs reproduce the raw trajectory bit-for-bit; the lossy one
    // must NOT — if it did, canonicalization would be vacuously untested.
    assert_eq!(fnvs[0].1, fnvs[1].1, "lossless must reproduce the raw trajectory");
    assert_ne!(fnvs[2].1, fnvs[0].1, "q8 should quantize onto a different trajectory");
}

/// The failure-free determinism above must survive a mid-run kill: the
/// survivor's recomputation of the lost shard goes through the same
/// canonicalization as the wire path, under both compressed codecs.
#[test]
fn wire_v4_chaos_kill_stays_bitwise_identical_under_compressed_codecs() {
    for codec in ["lossless", "q8"] {
        let mut cfg = test_cfg(&format!("codec_kill_{codec}"), 2, 8);
        cfg.grad_codec = codec.to_string();
        std::fs::remove_dir_all(&cfg.ckpt_dir).ok();

        let (addr, coord) = spawn_coordinator(cfg.clone());
        let w0 = spawn_codec_worker(0, &addr, codec, None);
        let w1 = spawn_codec_worker(1, &addr, codec, Some(r#"[{"kind":"kill","step":4}]"#));
        let outcome = coord.join().unwrap().unwrap_or_else(|e| panic!("{codec}: {e}"));
        let r0 = w0.join().unwrap().expect("survivor failed");
        let err = w1.join().unwrap().unwrap_err().to_string();
        assert!(err.contains("chaos: killed at step 4"), "{codec}: {err}");

        let reference = local::run_local(&cfg).unwrap();
        assert_eq!(
            weights_fingerprint(&outcome.weights),
            weights_fingerprint(&reference.weights),
            "{codec}: takeover must stay bitwise identical to the failure-free reference"
        );
        assert!(outcome.recovered >= 1, "{codec}: the killed shard was recovered");
        assert_eq!(r0.shutdown_reason, "done");
        assert_eq!(r0.weights_fnv, weights_fingerprint(&outcome.weights));
        std::fs::remove_dir_all(&cfg.ckpt_dir).ok();
    }
}

/// A worker offering a different codec than the session negotiated must be
/// rejected at the handshake with an explanatory error on BOTH sides —
/// never admitted to exchange frames it would misinterpret.
#[test]
fn wire_v4_codec_mismatch_is_rejected_at_the_handshake() {
    let cfg = test_cfg("codec_mismatch", 1, 4); // session codec: raw (default)
    let (addr, coord) = spawn_coordinator(cfg);
    let w0 = spawn_codec_worker(0, &addr, "q8", None);
    let cerr = coord.join().unwrap().unwrap_err().to_string();
    assert!(cerr.contains("offered grad codec"), "got: {cerr}");
    let werr = w0.join().unwrap().unwrap_err().to_string();
    assert!(werr.contains("coordinator rejected worker 0"), "got: {werr}");
}

/// Post-failover resume: session 1 loses a worker mid-run, so its final
/// shard files reflect the re-dealt surviving topology — and session 2
/// resumes from them with a DIFFERENT worker count. Reconciliation must
/// assemble the newest complete step from whatever files cover the model,
/// ignoring the dead worker's stale earlier-step shard.
#[test]
fn resume_reconciles_post_failover_topology_with_fewer_workers() {
    let mut cfg = test_cfg("resume_failover", 3, 8);
    cfg.ckpt_every = 2;
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();

    // Session 1: worker 2 dies at step 3, after the step-2 checkpoint wrote
    // its shard. Survivors take over its layer group, so the step-8 files
    // from workers 0 and 1 cover the whole model between them.
    let (addr, coord) = spawn_coordinator(cfg.clone());
    let w0 = spawn_worker(0, &addr);
    let w1 = spawn_worker(1, &addr);
    let w2 = spawn_chaos_worker(2, &addr, r#"[{"kind":"kill","step":3}]"#);
    let first = coord.join().unwrap().expect("session 1 failed");
    w0.join().unwrap().unwrap();
    w1.join().unwrap().unwrap();
    assert!(w2.join().unwrap().is_err());
    assert_eq!(first.final_step, 8);
    assert!(first.recovered >= 1);
    // The dead worker's shard file is still on disk at its last checkpoint
    // step — reconciliation must skip past it to the newer complete step.
    assert!(sumo::cluster::shard::shard_path(&cfg.ckpt_dir, 2, 3).exists());

    // Session 2: two workers, not three. The old 3-way group boundaries no
    // longer exist; each worker re-slices its new group out of the files.
    cfg.workers = 2;
    cfg.resume = true;
    cfg.steps = 3;
    let (addr, coord) = spawn_coordinator(cfg.clone());
    let w0 = spawn_worker(0, &addr);
    let w1 = spawn_worker(1, &addr);
    let second = coord.join().unwrap().expect("post-failover resume failed");
    let r0 = w0.join().unwrap().unwrap();
    let r1 = w1.join().unwrap().unwrap();
    assert_eq!(second.start_step, 8, "must resume from the newest complete step");
    assert_eq!(second.final_step, 11);
    assert_eq!((r0.final_step, r1.final_step), (11, 11));
    let fnv = weights_fingerprint(&second.weights);
    assert_eq!(r0.weights_fnv, fnv, "resumed replica diverged");
    assert_eq!(r1.weights_fnv, fnv, "resumed replica diverged");
    assert_ne!(fnv, first.fingerprint(), "resumed session must make progress");
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();
}

/// Genuinely missing shards (no step is fully covered) must fail the
/// resume with an explanatory error instead of silently restarting at 0.
#[test]
fn resume_with_a_lost_shard_fails_with_a_clean_error() {
    let mut cfg = test_cfg("resume_lost", 2, 4);
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();

    let (addr, coord) = spawn_coordinator(cfg.clone());
    let (w0, w1) = (spawn_worker(0, &addr), spawn_worker(1, &addr));
    coord.join().unwrap().unwrap();
    w0.join().unwrap().unwrap();
    w1.join().unwrap().unwrap();

    // Lose worker 0's shard: the surviving file covers only half the model,
    // so no step is complete and reconciliation must say so.
    std::fs::remove_file(sumo::cluster::shard::shard_path(&cfg.ckpt_dir, 0, 2)).unwrap();
    cfg.resume = true;
    let (addr, coord) = spawn_coordinator(cfg.clone());
    let w0 = spawn_worker(0, &addr);
    let w1 = spawn_worker(1, &addr);
    let cerr = coord.join().unwrap().unwrap_err().to_string();
    assert!(cerr.contains("failed while offering group state"), "got: {cerr}");
    let werr = w0.join().unwrap().unwrap_err().to_string();
    assert!(werr.contains("cover no complete step"), "got: {werr}");
    let werr = w1.join().unwrap().unwrap_err().to_string();
    assert!(werr.contains("cover no complete step"), "got: {werr}");
    std::fs::remove_dir_all(&cfg.ckpt_dir).ok();
}

#[test]
fn kill_all_aborts_the_join_phase() {
    let cfg = test_cfg("killall", 2, 10);
    let (addr, coord) = spawn_coordinator(cfg);
    coordinator::kill_all(&addr).unwrap();
    let outcome = coord.join().unwrap().unwrap();
    assert!(outcome.killed);
    assert_eq!(outcome.fingerprint(), 0);
}

#[test]
fn hostile_frames_are_rejected_before_allocation() {
    // A length prefix claiming 2^60 bytes must be rejected from the header
    // alone — decode never allocates the claimed size.
    let mut frame = Vec::new();
    frame.extend_from_slice(WIRE_MAGIC);
    frame.push(WIRE_VERSION);
    frame.push(1); // Hello tag
    frame.extend_from_slice(&(1u64 << 60).to_le_bytes());
    assert_eq!(frame.len(), HEADER_BYTES);
    let err = sumo::cluster::messages::decode(&frame).unwrap_err().to_string();
    assert!(err.contains("frame"), "got: {err}");

    // Truncated payload: header promises more bytes than are present.
    let mut good =
        encode(&Msg::Hello { worker_id: 3, task_support: TASK_SUPPORT_ALL, codec: 0 });
    good.truncate(good.len() - 2);
    assert!(sumo::cluster::messages::decode(&good).is_err());

    // Bad version byte.
    let mut bad =
        encode(&Msg::Hello { worker_id: 3, task_support: TASK_SUPPORT_ALL, codec: 0 });
    bad[4] = 99;
    let err = sumo::cluster::messages::decode(&bad).unwrap_err().to_string();
    assert!(err.contains("version"), "got: {err}");

    // And over a real socket: a coordinator that receives garbage during
    // join drops the connection and keeps listening (then gets killed).
    let cfg = test_cfg("hostile", 1, 5);
    let (addr, coord) = spawn_coordinator(cfg);
    {
        use std::io::Write;
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
    }
    std::thread::sleep(Duration::from_millis(100));
    coordinator::kill_all(&addr).unwrap();
    let outcome = coord.join().unwrap().unwrap();
    assert!(outcome.killed, "garbage connection must not take down the join");
}
