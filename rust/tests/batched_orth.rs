//! Batched orthogonalization equivalence: `orth_svd_batched_into` must be
//! **bitwise identical** to N independent `orth_svd_into` calls across shape
//! classes, batch sizes, orientations, condition numbers up to 1e6, and
//! serial vs pool-chunked dispatch. This is the contract the three-phase
//! SUMO step dispatch (and the future Pallas grid-axis kernel) stands on —
//! the batched path may only change loop interleaving, never arithmetic.

use sumo::linalg::orth::polar_defect;
use sumo::linalg::{
    orth_svd_batched_into, orth_svd_batched_multi_into, orth_svd_into, BatchOrthScratch,
    BatchOrthTask, Mat, OrthScratch,
};
use sumo::testing::{check, gen, PropConfig};
use sumo::util::threadpool::ThreadPool;
use sumo::util::Rng;

/// Reference: run each problem through the single-matrix kernel.
fn singles(ms: &[Mat]) -> Vec<Mat> {
    ms.iter()
        .map(|m| {
            let mut out = Mat::zeros(m.rows, m.cols);
            let mut ws = OrthScratch::new(m.rows, m.cols);
            orth_svd_into(m, &mut out, &mut ws);
            out
        })
        .collect()
}

/// Run the batched kernel over `ms` (which must share one shape class) and
/// assert bitwise agreement with the single-matrix path.
fn assert_batched_bitwise(ms: &[Mat], pool: Option<&ThreadPool>, label: &str) -> Vec<Mat> {
    let (r0, c0) = ms[0].shape();
    let (k, l) = (r0.min(c0), r0.max(c0));
    let want = singles(ms);
    let mut ws = BatchOrthScratch::new(ms.len(), k, l);
    let mut outs: Vec<Mat> = ms.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
    let ins: Vec<&Mat> = ms.iter().collect();
    let mut out_refs: Vec<&mut Mat> = outs.iter_mut().collect();
    orth_svd_batched_into(&ins, &mut out_refs, &mut ws, pool);
    for (i, (got, want)) in outs.iter().zip(&want).enumerate() {
        assert!(got.is_finite(), "{label}: problem {i} not finite");
        assert_eq!(
            got.max_diff(want),
            0.0,
            "{label}: problem {i} of {} diverged from the single-matrix path",
            ms.len()
        );
    }
    outs
}

#[test]
fn prop_batched_matches_singles_across_shapes_and_batches() {
    let pool = ThreadPool::new(4);
    check(
        PropConfig {
            cases: 48,
            seed: 0xBA7C,
        },
        "orth_svd_batched_into ≡ N× orth_svd_into (bitwise)",
        |rng| {
            let k = 1 + rng.below_usize(8); // small side 1..=8
            let l = k + rng.below_usize(48); // large side k..k+48
            let batch = 1 + rng.below_usize(17); // 1..=17 problems
            let ms: Vec<Mat> = (0..batch)
                .map(|i| {
                    // Mix orientations within one shape class.
                    if i % 2 == 0 {
                        Mat::randn(k, l, 1.0, rng)
                    } else {
                        Mat::randn(l, k, 1.0, rng)
                    }
                })
                .collect();
            (k, l, ms)
        },
        |(k, l, ms)| {
            let want = singles(ms);
            for pool_opt in [None, Some(&pool)] {
                let mut ws = BatchOrthScratch::new(ms.len(), *k, *l);
                let mut outs: Vec<Mat> = ms.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
                let ins: Vec<&Mat> = ms.iter().collect();
                let mut out_refs: Vec<&mut Mat> = outs.iter_mut().collect();
                orth_svd_batched_into(&ins, &mut out_refs, &mut ws, pool_opt);
                for (i, (got, w)) in outs.iter().zip(&want).enumerate() {
                    if got.max_diff(w) != 0.0 {
                        return Err(format!(
                            "({k},{l}) batch {} problem {i} pooled={}: diff {}",
                            ms.len(),
                            pool_opt.is_some(),
                            got.max_diff(w)
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn batched_bitwise_on_ill_conditioned_moments() {
    // κ up to 1e6 — where the f64 one-sided Jacobi accuracy matters most —
    // stacked with well-conditioned neighbors in the same batch, so masked
    // convergence (problems finishing at different sweeps) is exercised.
    let mut rng = Rng::new(0x1CE6);
    let pool = ThreadPool::new(3);
    for &kappa in &[1e2f32, 1e4, 1e6] {
        let mut ms = Vec::new();
        for i in 0..9 {
            let k = if i % 3 == 2 { kappa } else { 1.0 + i as f32 };
            ms.push(gen::conditioned_mat(&mut rng, 6, 48, k));
        }
        let outs = assert_batched_bitwise(&ms, Some(&pool), &format!("kappa={kappa}"));
        for o in &outs {
            assert!(
                polar_defect(o) < 1e-4,
                "κ={kappa}: batched defect {}",
                polar_defect(o)
            );
        }
    }
}

#[test]
fn batched_handles_rank_deficient_problems_in_the_mix() {
    let mut rng = Rng::new(0xDEF1);
    let mut ms = Vec::new();
    for i in 0..8 {
        if i % 2 == 0 {
            // Rank-2 content in a 4×32 moment (duplicated, scaled rows).
            let a = Mat::randn(2, 32, 1.0, &mut rng);
            let mut m = Mat::zeros(4, 32);
            for r in 0..2 {
                m.row_mut(r).copy_from_slice(a.row(r));
                let scaled: Vec<f32> = a.row(r).iter().map(|x| 0.5 * x).collect();
                m.row_mut(r + 2).copy_from_slice(&scaled);
            }
            ms.push(m);
        } else {
            ms.push(Mat::randn(4, 32, 1.0, &mut rng));
        }
    }
    assert_batched_bitwise(&ms, None, "rank-deficient mix");
}

#[test]
fn multi_class_dispatch_matches_singles_bitwise() {
    // The grouped SUMO step's phase-2 shape: several classes at once, some
    // singleton — all flattened into one pool dispatch. Every problem must
    // still match its single-matrix result bitwise, serial and pooled.
    let mut rng = Rng::new(0x3C1A);
    let pool = ThreadPool::new(3);
    // (class shape, batch size): includes two singleton classes.
    let classes = [(4usize, 32usize, 6usize), (4, 48, 1), (8, 16, 3), (2, 64, 1)];
    let ms_per_class: Vec<Vec<Mat>> = classes
        .iter()
        .map(|&(k, l, n)| (0..n).map(|_| Mat::randn(k, l, 1.0, &mut rng)).collect())
        .collect();
    let want: Vec<Vec<Mat>> = ms_per_class.iter().map(|ms| singles(ms)).collect();
    for use_pool in [false, true] {
        let mut scratches: Vec<BatchOrthScratch> = classes
            .iter()
            .map(|&(k, l, n)| BatchOrthScratch::new(n, k, l))
            .collect();
        let mut outs_per_class: Vec<Vec<Mat>> = ms_per_class
            .iter()
            .map(|ms| ms.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect())
            .collect();
        let mut tasks: Vec<BatchOrthTask<'_>> = Vec::new();
        for ((ms, outs), ws) in ms_per_class
            .iter()
            .zip(outs_per_class.iter_mut())
            .zip(scratches.iter_mut())
        {
            tasks.push(BatchOrthTask {
                inputs: ms.iter().collect(),
                outs: outs.iter_mut().collect(),
                ws,
            });
        }
        orth_svd_batched_multi_into(tasks, use_pool.then_some(&pool));
        for (c, (outs, want)) in outs_per_class.iter().zip(&want).enumerate() {
            for (i, (got, w)) in outs.iter().zip(want).enumerate() {
                assert_eq!(
                    got.max_diff(w),
                    0.0,
                    "class {c} problem {i} pooled={use_pool} diverged"
                );
            }
        }
    }
}

#[test]
fn batched_bitwise_across_resident_pool_sizes() {
    // The acceptance sweep: one mixed-orientation batch through resident
    // pools of size 1 (inline), 2, and 8 (more workers than chunks) must
    // stay bitwise identical to the single-matrix path.
    let mut rng = Rng::new(0x9001);
    let ms: Vec<Mat> = (0..11)
        .map(|i| {
            if i % 2 == 0 {
                Mat::randn(4, 40, 1.0, &mut rng)
            } else {
                Mat::randn(40, 4, 1.0, &mut rng)
            }
        })
        .collect();
    for workers in [1usize, 2, 8] {
        let pool = ThreadPool::new(workers);
        assert_batched_bitwise(&ms, Some(&pool), &format!("pool size {workers}"));
    }
}

#[test]
fn scratch_reuse_across_calls_stays_bitwise() {
    // One scratch, several rounds with fresh data (the steady-state pattern
    // of the grouped SUMO step): no state may leak between rounds. Also runs
    // a partial batch (fewer problems than capacity).
    let mut rng = Rng::new(0x5EED);
    let pool = ThreadPool::new(2);
    let mut ws = BatchOrthScratch::new(12, 4, 64);
    for round in 0..4 {
        let n = if round == 2 { 5 } else { 12 };
        let ms: Vec<Mat> = (0..n).map(|_| Mat::randn(4, 64, 1.0, &mut rng)).collect();
        let want = singles(&ms);
        let mut outs: Vec<Mat> = ms.iter().map(|_| Mat::zeros(4, 64)).collect();
        let ins: Vec<&Mat> = ms.iter().collect();
        let mut out_refs: Vec<&mut Mat> = outs.iter_mut().collect();
        orth_svd_batched_into(&ins, &mut out_refs, &mut ws, Some(&pool));
        for (got, w) in outs.iter().zip(&want) {
            assert_eq!(got.max_diff(w), 0.0, "round {round} leaked scratch state");
        }
    }
}
