//! Property-based invariants (proptest-lite) over the coordinator's
//! numerical substrates: orthogonality, projection geometry, transport,
//! limiter behaviour, batching partitions, all-reduce algebra.

use sumo::coordinator::allreduce_mean;
use sumo::data::Batch;
use sumo::linalg::{
    matmul, matmul_at_b, mgs_qr, newton_schulz5, orth_svd, randomized_range, Mat, RsvdOpts,
};
use sumo::linalg::qr::orthogonality_defect;
use sumo::optim::subspace::SubspaceState;
use sumo::optim::NormGrowthLimiter;
use sumo::testing::{check, gen, PropConfig};
use sumo::util::Rng;

fn cfg(cases: usize) -> PropConfig {
    PropConfig {
        cases,
        seed: 0x5D0_7E57,
    }
}

#[test]
fn prop_orth_svd_is_semi_orthogonal() {
    check(
        cfg(40),
        "orth_svd semi-orthogonal",
        |rng| gen::mat(rng, 2..12, 12..80),
        |m| {
            let o = orth_svd(m);
            let g = sumo::linalg::matmul_a_bt(&o, &o);
            for i in 0..g.rows {
                for j in 0..g.cols {
                    let target = if i == j { 1.0 } else { 0.0 };
                    if (g[(i, j)] - target).abs() > 5e-3 {
                        return Err(format!("OOᵀ[{i},{j}] = {}", g[(i, j)]));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_orth_svd_idempotent() {
    check(
        cfg(30),
        "orth(orth(M)) == orth(M)",
        |rng| gen::mat(rng, 2..10, 10..60),
        |m| {
            let o1 = orth_svd(m);
            let o2 = orth_svd(&o1);
            if o1.max_diff(&o2) > 5e-3 {
                return Err(format!("not idempotent: {}", o1.max_diff(&o2)));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_orth_never_worse_than_ns5_against_exact() {
    // Lemma 3.2 consequence: exact orth error is 0, NS5's grows with κ.
    check(
        cfg(20),
        "exact vs ns5 error ordering",
        |rng| {
            let kappa = 10.0f32.powf(1.0 + 2.0 * rng.f32());
            gen::conditioned_mat(rng, 6, 48, kappa)
        },
        |m| {
            let exact = orth_svd(m);
            let ns = newton_schulz5(m, 5);
            // Exact output orthogonality defect must beat NS5's.
            let d_exact = sumo::linalg::orth::polar_defect(&exact);
            let d_ns = sumo::linalg::orth::polar_defect(&ns);
            if d_exact > d_ns + 1e-3 {
                return Err(format!("exact defect {d_exact} > ns5 {d_ns}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_qr_projector_idempotent() {
    check(
        cfg(30),
        "QQᵀ idempotent projector",
        |rng| gen::mat(rng, 8..40, 2..8),
        |a| {
            let (q, _) = mgs_qr(a);
            if orthogonality_defect(&q) > 1e-3 {
                return Err("Q not orthonormal".into());
            }
            // P = QQᵀ; P² = P.
            let p = sumo::linalg::matmul_a_bt(&q, &q);
            let p2 = matmul(&p, &p);
            if p2.max_diff(&p) > 1e-3 {
                return Err(format!("P² != P: {}", p2.max_diff(&p)));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_range_finder_captures_lowrank() {
    check(
        cfg(20),
        "rSVD exact on low-rank",
        |rng| {
            let r = 1 + rng.below_usize(5);
            let m = 30 + rng.below_usize(30);
            let n = 20 + rng.below_usize(30);
            (gen::lowrank_mat(rng, m, n, r), r)
        },
        |(a, r)| {
            let mut rng = Rng::new(a.data.len() as u64);
            let q = randomized_range(a, *r, RsvdOpts::default(), &mut rng);
            let qta = matmul_at_b(&q, a);
            let proj = matmul(&q, &qta);
            let mut resid = a.clone();
            resid.axpy(-1.0, &proj);
            let rel = resid.fro() / a.fro().max(1e-20);
            if rel > 1e-2 {
                return Err(format!("residual {rel}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_transport_is_norm_nonexpanding() {
    // ‖R M‖ ≤ ‖M‖ since R = Q_newᵀ Q_old has spectral norm ≤ 1.
    check(
        cfg(25),
        "moment transport non-expanding",
        |rng| {
            let g1 = gen::lowrank_mat(rng, 40, 24, 4);
            let g2 = gen::lowrank_mat(rng, 40, 24, 4);
            let seed = rng.next_u64();
            (g1, g2, seed)
        },
        |(g1, g2, seed)| {
            let mut ss = SubspaceState::new(40, 24, 4, 1000, Rng::new(*seed));
            ss.refresh(g1, None);
            let m0 = ss.project(g1);
            let norm0 = m0.fro();
            let m1 = ss.refresh(g2, Some(m0)).unwrap();
            if m1.fro() > norm0 * (1.0 + 1e-3) {
                return Err(format!("transport expanded {} -> {}", norm0, m1.fro()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_limiter_caps_ratio() {
    check(
        cfg(40),
        "limiter growth ratio ≤ γ",
        |rng| {
            let n1 = 0.1 + 10.0 * rng.f32();
            let n2 = 0.1 + 100.0 * rng.f32();
            (n1, n2)
        },
        |(n1, n2)| {
            let mut nl = NormGrowthLimiter::new(1.1, true);
            let mut o1 = Mat::from_slice(1, 1, &[*n1]);
            nl.apply(&mut o1);
            let mut o2 = Mat::from_slice(1, 1, &[*n2]);
            nl.apply(&mut o2);
            if o2.fro() > 1.1 * n1 + 1e-4 && o2.fro() > *n2 + 1e-4 {
                return Err(format!("o2 {} exceeds γ·{n1} and original {n2}", o2.fro()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_allreduce_equals_arithmetic_mean() {
    check(
        cfg(25),
        "allreduce = mean",
        |rng| {
            let shards = 1 + rng.below_usize(7);
            let mats: Vec<Vec<Mat>> = (0..shards)
                .map(|_| vec![Mat::randn(6, 5, 1.0, rng)])
                .collect();
            mats
        },
        |shards| {
            let mut want = Mat::zeros(6, 5);
            for s in shards {
                want.axpy(1.0 / shards.len() as f32, &s[0]);
            }
            let mut work = shards.clone();
            let got = allreduce_mean(&mut work);
            if got[0].max_diff(&want) > 1e-4 {
                return Err(format!("diff {}", got[0].max_diff(&want)));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batch_rows_partition_stream() {
    check(
        cfg(20),
        "LM batch shift-partition",
        |rng| {
            let seq = 4 + rng.below_usize(12);
            let b = 1 + rng.below_usize(5);
            let seqs: Vec<Vec<u32>> = (0..b)
                .map(|_| (0..seq + 1).map(|_| rng.below(1000) as u32).collect())
                .collect();
            (seqs, seq)
        },
        |(seqs, seq)| {
            let batch = Batch::from_sequences(seqs, *seq);
            for (i, s) in seqs.iter().enumerate() {
                for t in 0..*seq {
                    if batch.inputs[i * seq + t] != s[t] {
                        return Err("input mismatch".into());
                    }
                    if batch.targets[i * seq + t] != s[t + 1] {
                        return Err("target not shifted".into());
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip_random_values() {
    use sumo::util::json::Json;
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => Json::Num((rng.f64() * 2000.0 - 1000.0).round() / 8.0),
            3 => Json::Str(format!("s{}-\"q\"-\n", rng.below(100))),
            4 => Json::arr((0..rng.below_usize(4)).map(|_| random_json(rng, depth - 1))),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.below_usize(4) {
                    m.insert(format!("k{i}"), random_json(rng, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    check(
        cfg(60),
        "json parse(dump(x)) == x",
        |rng| random_json(rng, 3),
        |j| {
            let re = Json::parse(&j.dump()).map_err(|e| e.to_string())?;
            if &re != j {
                return Err(format!("mismatch: {} vs {}", re.dump(), j.dump()));
            }
            let re2 = Json::parse(&j.pretty()).map_err(|e| e.to_string())?;
            if &re2 != j {
                return Err("pretty mismatch".into());
            }
            Ok(())
        },
    );
}
