//! Wire v4 gradient-frame codec conformance battery.
//!
//! Three layers of pinning, from the outside in:
//!
//! 1. **Exactness / round-trip properties** over adversarial mats — NaN
//!    payloads, ±Inf, -0.0, denormals, empty / 1×n / n×1 shapes — compared
//!    *bitwise* (`to_bits`), never by float equality.
//! 2. **Determinism and idempotence** of the lossy codec: encode is a pure
//!    function of the mats, decode∘encode is a projection with encode∘decode
//!    a fixed point, and canonicalize produces exactly the wire image. This
//!    is the property the whole cluster determinism story leans on.
//! 3. **Golden bytes**: hand-computed envelopes pinned byte-for-byte, so an
//!    accidental wire-format change fails loudly instead of silently
//!    breaking cross-version clusters.

use sumo::cluster::codec::{decode_mats, encode_mats, GradCodec};
use sumo::cluster::weights_fingerprint;
use sumo::linalg::Mat;
use sumo::util::Rng;

const ALL_CODECS: [GradCodec; 3] = [GradCodec::Raw, GradCodec::Lossless, GradCodec::Q8Det];

/// Bit patterns of every element, mat by mat — the only honest equality
/// for payloads that may carry NaN or -0.0.
fn bits(mats: &[Mat]) -> Vec<Vec<u32>> {
    mats.iter().map(|m| m.data.iter().map(|x| x.to_bits()).collect()).collect()
}

/// Mats chosen to hit every decoder edge: empty, degenerate shapes, all
/// the IEEE specials, subnormals, extreme magnitudes, and realistic
/// small-magnitude gradient noise.
fn adversarial_mats() -> Vec<Mat> {
    let mut rng = Rng::new(0x9E37);
    vec![
        Mat::from_vec(0, 0, vec![]),
        Mat::from_vec(1, 8, vec![
            f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            -0.0,
            f32::from_bits(1), // smallest subnormal
            f32::MIN_POSITIVE,
            f32::MAX,
            -f32::MAX,
        ]),
        Mat::from_vec(1, 7, vec![0.0; 7]),
        Mat::from_vec(5, 1, vec![1.0, -2.0, 0.5, -0.25, 3.75]),
        Mat::randn(11, 3, 1e-3, &mut rng),
        Mat::randn(2, 17, 1e4, &mut rng),
    ]
}

#[test]
fn raw_and_lossless_are_exact_for_arbitrary_f32() {
    let mats = adversarial_mats();
    for codec in [GradCodec::Raw, GradCodec::Lossless] {
        let dec = decode_mats(codec, &encode_mats(codec, &mats)).unwrap();
        assert_eq!(bits(&dec), bits(&mats), "{codec:?} must be bit-exact");
        for (a, b) in dec.iter().zip(&mats) {
            assert_eq!(a.shape(), b.shape(), "{codec:?} shape drift");
        }
    }
}

#[test]
fn lossless_shrinks_gradient_like_payloads() {
    // Same-magnitude gradients share sign/exponent bytes, so the
    // transposed planes must RLE below Raw. Not a property of arbitrary
    // data — pinned only for the payload shape the cluster actually ships.
    let mut rng = Rng::new(77);
    let mats = vec![Mat::randn(64, 64, 1e-3, &mut rng)];
    let raw = encode_mats(GradCodec::Raw, &mats).len();
    let lossless = encode_mats(GradCodec::Lossless, &mats).len();
    assert!(
        lossless < raw,
        "lossless ({lossless} B) should beat raw ({raw} B) on gradient noise"
    );
}

#[test]
fn q8_is_idempotent_under_every_roundtrip_depth() {
    let mats = adversarial_mats();
    let enc1 = encode_mats(GradCodec::Q8Det, &mats);
    let dec1 = decode_mats(GradCodec::Q8Det, &enc1).unwrap();
    let enc2 = encode_mats(GradCodec::Q8Det, &dec1);
    assert_eq!(enc2, enc1, "re-encoding decoded mats must reproduce the bytes");
    let dec2 = decode_mats(GradCodec::Q8Det, &enc2).unwrap();
    assert_eq!(bits(&dec2), bits(&dec1), "second decode must be a fixed point");
    // Canonicalize IS the wire image: what a worker quantizes locally is
    // bit-equal to what any peer decodes off the wire.
    let mut canon = adversarial_mats();
    GradCodec::Q8Det.canonicalize(&mut canon);
    assert_eq!(bits(&canon), bits(&dec1));
}

#[test]
fn encode_is_a_pure_function_across_processes() {
    // Cross-process determinism, single-process stand-in: two independently
    // constructed (bit-equal) mat sets — as two workers would compute from
    // the same seeded streams — must encode to identical bytes under every
    // codec, and the decoded image must fingerprint identically.
    for codec in ALL_CODECS {
        let a = encode_mats(codec, &adversarial_mats());
        let b = encode_mats(codec, &adversarial_mats());
        assert_eq!(a, b, "{codec:?} encode differs across identical inputs");
        let fa = weights_fingerprint(&decode_mats(codec, &a).unwrap());
        let fb = weights_fingerprint(&decode_mats(codec, &b).unwrap());
        assert_eq!(fa, fb, "{codec:?} decoded fingerprints differ");
    }
}

#[test]
fn canonicalize_is_identity_for_exact_codecs_and_idempotent_for_q8() {
    let reference = adversarial_mats();
    let mut mats = adversarial_mats();
    GradCodec::Raw.canonicalize(&mut mats);
    GradCodec::Lossless.canonicalize(&mut mats);
    assert_eq!(bits(&mats), bits(&reference), "exact codecs must not touch data");
    GradCodec::Q8Det.canonicalize(&mut mats);
    let once = bits(&mats);
    GradCodec::Q8Det.canonicalize(&mut mats);
    assert_eq!(bits(&mats), once, "canonicalize must be a projection");
}

#[test]
fn golden_bytes_raw() {
    // Envelope: codec id, u32 mat count, then u32 rows, u32 cols, LE f32s.
    let mats = vec![Mat::from_vec(1, 1, vec![1.0])];
    let enc = encode_mats(GradCodec::Raw, &mats);
    assert_eq!(
        enc,
        vec![0, 1, 0, 0, 0, 1, 0, 0, 0, 1, 0, 0, 0, 0x00, 0x00, 0x80, 0x3f],
        "raw wire image changed — that breaks every deployed v4 peer"
    );
}

#[test]
fn golden_bytes_lossless_zero_pages() {
    // An all-zero mat: dims, then four PLANE_ZERO mode bytes and nothing
    // else. The zero page is the cheapest section the format has.
    let mats = vec![Mat::from_vec(1, 2, vec![0.0, 0.0])];
    let enc = encode_mats(GradCodec::Lossless, &mats);
    assert_eq!(
        enc,
        vec![1, 1, 0, 0, 0, 1, 0, 0, 0, 2, 0, 0, 0, 0, 0, 0, 0],
        "lossless wire image changed — that breaks every deployed v4 peer"
    );
    // And an empty mat is dims + four zero pages, nothing more.
    let empty = encode_mats(GradCodec::Lossless, &[Mat::from_vec(0, 0, vec![])]);
    assert_eq!(empty, vec![1, 1, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0]);
}

#[test]
fn golden_bytes_q8() {
    // [1.0, -2.0]: amax 2.0 → minimal power-of-two scale with
    // 127·s ≥ 2.0 is s = 2⁻⁵ = 0.03125 (f32 LE 00 00 00 3d). Codes:
    // 1.0/s = 32 = 0x20, -2.0/s = -64 = 0xc0 as a byte.
    let mats = vec![Mat::from_vec(1, 2, vec![1.0, -2.0])];
    let enc = encode_mats(GradCodec::Q8Det, &mats);
    assert_eq!(
        enc,
        vec![2, 1, 0, 0, 0, 1, 0, 0, 0, 2, 0, 0, 0, 0x00, 0x00, 0x00, 0x3d, 0x20, 0xc0],
        "q8 wire image changed — that breaks every deployed v4 peer"
    );
    // The decode must land exactly on the quantized grid, not nearby.
    let dec = decode_mats(GradCodec::Q8Det, &enc).unwrap();
    assert_eq!(dec[0].data, vec![1.0, -2.0], "±2^k values are on the q8 grid");
}

#[test]
fn q8_specials_map_deterministically() {
    let mats = vec![Mat::from_vec(1, 4, vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 1.0])];
    let dec = decode_mats(GradCodec::Q8Det, &encode_mats(GradCodec::Q8Det, &mats)).unwrap();
    // amax sees only the finite 1.0 → minimal power-of-two scale with
    // 127·s ≥ 1.0 is s = 2⁻⁶ (127·2⁻⁷ ≈ 0.99 falls short). NaN → 0,
    // ±Inf clamp to ±127·s.
    let s = 1.0 / 64.0;
    assert_eq!(dec[0].data, vec![0.0, 127.0 * s, -127.0 * s, 1.0]);
}

#[test]
fn every_codec_rejects_the_other_ids_and_truncation() {
    let mats = vec![Mat::from_vec(2, 3, vec![1.0, -2.0, 3.0, -4.0, 5.5, -6.5])];
    for codec in ALL_CODECS {
        let enc = encode_mats(codec, &mats);
        for other in ALL_CODECS {
            if other == codec {
                continue;
            }
            let err = decode_mats(other, &enc).unwrap_err().to_string();
            assert!(err.contains("codec mismatch"), "{codec:?} vs {other:?}: {err}");
        }
        for cut in 0..enc.len() {
            assert!(
                decode_mats(codec, &enc[..cut]).is_err(),
                "{codec:?} accepted a {cut}-byte truncation"
            );
        }
    }
}
