//! Tables 4 & 5 — math-reasoning fine-tuning (GSM8K-style, zero-shot and
//! few-shot): Base model vs GaLore vs LoRA vs SUMO at a fixed rank.
//!
//! The paper fine-tunes Phi-2 2.7B / LLaMA 3B at rank 64 on real GSM8K;
//! here a pretrained-by-us `mini` LM is fine-tuned on *compact* synthetic
//! arithmetic ("7+3*2=") sized for its byte-level seq-64 context, and
//! scored by greedy-decode exact match (DESIGN.md §3). Expected shape:
//! every fine-tune ≫ base; SUMO ≥ GaLore ≥ LoRA.

use sumo::bench::{scaled, TableWriter};
use sumo::config::{OptimCfg, OptimKind, Schedule, TrainCfg};
use sumo::coordinator::Coordinator;
use sumo::data::math_tasks::{self, MathTaskCfg};
use sumo::data::tokenizer::BpeLiteTokenizer;
use sumo::data::Batch;
use sumo::runtime::Runtime;

/// Left-padded decode context: the model's final position is the last
/// prompt byte (no trailing EOS/PAD), as LM decoding requires.
fn decode_context(tok: &BpeLiteTokenizer, prompt: &str, seq: usize) -> Vec<u32> {
    let mut ids = tok.encode(prompt);
    ids.pop(); // strip EOS
    if ids.len() > seq {
        ids = ids[ids.len() - seq..].to_vec();
    }
    let mut out = vec![0u32; seq - ids.len()];
    out.extend(ids);
    out
}

/// Greedy-decode 3 tokens and exact-match the answer digits.
fn eval_exact_match(
    coord: &Coordinator,
    tok: &BpeLiteTokenizer,
    cfg: &MathTaskCfg,
    n_problems: usize,
) -> anyhow::Result<f64> {
    let batch = coord.runner.batch;
    let seq = coord.runner.seq_len();
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut idx = 0u64;
    while total < n_problems {
        let problems: Vec<_> = (0..batch)
            .map(|i| math_tasks::generate(cfg, 99, "dev", idx + i as u64))
            .collect();
        idx += batch as u64;
        let mut contexts: Vec<Vec<u32>> = problems
            .iter()
            .map(|p| decode_context(tok, &p.prompt, seq))
            .collect();
        let mut decoded: Vec<Vec<u32>> = vec![Vec::new(); batch];
        for _ in 0..3 {
            let flat: Vec<u32> = contexts.iter().flatten().copied().collect();
            let logits = coord.runner.lm_logits(&coord.params, &flat)?;
            for (b, row) in logits.iter().enumerate() {
                let mut best = 3usize; // never emit PAD/BOS/EOS
                for (i, &x) in row.iter().enumerate().skip(3) {
                    if x > row[best] {
                        best = i;
                    }
                }
                decoded[b].push(best as u32);
                contexts[b].remove(0);
                contexts[b].push(best as u32);
            }
        }
        for (p, d) in problems.iter().zip(&decoded) {
            if math_tasks::exact_match(&tok.decode(d), p.answer) {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(correct as f64 / total as f64)
}

/// Supervised fine-tune: "expr=answer;" streams packed into LM batches.
fn finetune(
    coord: &mut Coordinator,
    tok: &BpeLiteTokenizer,
    cfg: &MathTaskCfg,
    steps: usize,
) -> anyhow::Result<()> {
    let batch = coord.runner.batch;
    let seq = coord.runner.seq_len();
    let tcfg = TrainCfg {
        steps,
        schedule: Schedule::CosineWarmup {
            warmup: 5,
            min_ratio: 0.1,
        },
        ..TrainCfg::default()
    };
    let mut problem_idx = 0u64;
    for step in 0..steps {
        let mut full = Vec::with_capacity(batch * (seq + 1));
        for _ in 0..batch {
            // Pack several problems per row so every position carries signal.
            let mut ids: Vec<u32> = vec![1]; // BOS
            while ids.len() < seq + 1 {
                let p = math_tasks::generate(cfg, 7, "train", problem_idx);
                problem_idx += 1;
                let text = format!("{}{};", p.prompt, p.answer);
                let mut chunk = tok.encode(&text);
                chunk.remove(0); // drop BOS
                chunk.pop(); // drop EOS
                ids.extend(chunk);
            }
            ids.truncate(seq + 1);
            full.extend(ids);
        }
        let b = Batch::from_pair(&full, batch, seq);
        coord.train_iteration(&b, tcfg.lr_mult(step))?;
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_default_artifacts()?;
    let tok = BpeLiteTokenizer::bytes_only();
    let steps = scaled(600);
    let n_eval = 64;
    for (label, tag, task_cfg) in [
        ("Table 4 (zero-shot)", "zeroshot", MathTaskCfg::compact_zero_shot()),
        ("Table 5 (few-shot)", "fewshot", MathTaskCfg::compact_few_shot(3)),
    ] {
        let mut table = TableWriter::new(
            &format!("table45_{tag}"),
            &["Method", "Rank", "Accuracy (exact match)"],
        );
        // Base model: pretrained on the generic corpus only.
        let base_cfg = OptimCfg::new(OptimKind::Sumo).with_lr(0.02).with_rank(8).with_update_freq(50);
        let mut base = Coordinator::native(&rt, "mini_lm", &base_cfg, 42, 1)?;
        {
            use sumo::train::Trainer;
            let tcfg = TrainCfg {
                steps: scaled(80),
                log_every: 1_000_000,
                eval_batches: 2,
                ..TrainCfg::default()
            };
            Trainer::new(tcfg).pretrain(&mut base, None)?;
        }
        let base_params = base.params.tensors.clone();
        let base_acc = eval_exact_match(&base, &tok, &task_cfg, n_eval)?;
        table.row(&["Base Model".into(), "8".into(), format!("{:.2}%", 100.0 * base_acc)]);
        eprintln!("{label}: base acc {base_acc:.3}");

        for kind in [OptimKind::GaLore, OptimKind::Lora, OptimKind::Sumo] {
            let lr = if kind == OptimKind::Lora { 2e-3 } else { 2e-2 };
            let ocfg = OptimCfg::new(kind).with_lr(lr).with_rank(8).with_update_freq(50);
            let mut coord = Coordinator::native(&rt, "mini_lm", &ocfg, 42, 1)?;
            coord.set_params(sumo::model::ParamStore {
                cfg: coord.params.cfg.clone(),
                tensors: base_params.clone(),
            });
            finetune(&mut coord, &tok, &task_cfg, steps)?;
            let acc = eval_exact_match(&coord, &tok, &task_cfg, n_eval)?;
            table.row(&[
                kind.paper_name().into(),
                "8".into(),
                format!("{:.2}%", 100.0 * acc),
            ]);
            eprintln!("{label}: {} acc {acc:.3}", kind.paper_name());
        }
        table.finish().unwrap();
    }
    println!("\npaper-shape checks: fine-tuned rows ≫ base; SUMO highest (Tables 4-5).");
    Ok(())
}
