//! Figure 2 — convergence speed on QNLI: SUMO (SVD) vs SUMO (NS5) vs
//! GaLore. The paper reports ~1.6× fewer steps to reach GaLore's final
//! accuracy. We run the three fine-tunes with identical budgets, log the
//! accuracy-vs-step curves, and report the steps-to-target ratios.

use sumo::bench::{scaled, TableWriter};
use sumo::config::{OptimCfg, OptimKind, Schedule, TrainCfg};
use sumo::coordinator::Coordinator;
use sumo::data::glue::GlueTask;
use sumo::runtime::Runtime;
use sumo::train::Trainer;
use sumo::util::plot::ascii_plot;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_default_artifacts()?;
    let steps = scaled(240);
    let eval_every = (steps / 16).max(2);
    let mut curves: Vec<(&'static str, Vec<(f64, f64)>)> = Vec::new();
    let mut t = TableWriter::new("fig2_convergence", &["step", "galore", "sumo_ns5", "sumo_svd"]);
    let mut table_rows: std::collections::BTreeMap<usize, [f64; 3]> = Default::default();

    for (col, kind, label) in [
        (0usize, OptimKind::GaLore, "GaLore"),
        (1, OptimKind::SumoNs5, "SUMO-NS5"),
        (2, OptimKind::Sumo, "SUMO-SVD"),
    ] {
        let ocfg = OptimCfg::new(kind)
            .with_lr(0.02)
            .with_rank(8)
            .with_update_freq(50);
        let tcfg = TrainCfg {
            steps,
            eval_every,
            eval_batches: 10,
            log_every: 1_000_000,
            seed: 5,
            schedule: Schedule::CosineWarmup {
                warmup: 5,
                min_ratio: 0.1,
            },
            ..TrainCfg::default()
        };
        let mut coord = Coordinator::native(&rt, "micro_cls2", &ocfg, tcfg.seed, 1)?;
        let task =
            GlueTask::by_name("QNLI", coord.runner.cfg.vocab, coord.runner.seq_len()).unwrap();
        let report = Trainer::new(tcfg).finetune_glue(&mut coord, &task)?;
        for &(s, m) in &report.curve {
            table_rows.entry(s).or_insert([f64::NAN; 3])[col] = m;
        }
        curves.push((label, report.curve.iter().map(|&(s, m)| (s as f64, m)).collect()));
        println!("{label:<10} final acc {:.4} ({:.1}s)", report.metric, report.seconds);
    }
    for (step, row) in &table_rows {
        t.row(&[
            format!("{step}"),
            format!("{:.4}", row[0]),
            format!("{:.4}", row[1]),
            format!("{:.4}", row[2]),
        ]);
    }
    t.finish().unwrap();

    let plot_series: Vec<(&str, &[(f64, f64)])> =
        curves.iter().map(|(n, c)| (*n, c.as_slice())).collect();
    println!("{}", ascii_plot(&plot_series, 70, 14));

    // Steps-to-target on running-best (cummax) curves against a fixed
    // target below saturation — the protocol behind the paper's "~1.6x
    // fewer optimization steps" claim, robust to eval noise.
    let target = 0.80f64;
    let steps_to = |c: &[(f64, f64)]| {
        let mut best = 0.0f64;
        for (s, m) in c {
            best = best.max(*m);
            if best >= target {
                return *s;
            }
        }
        f64::INFINITY
    };
    let s_galore = steps_to(&curves[0].1).max(1.0);
    let s_ns5 = steps_to(&curves[1].1).max(1.0);
    let s_svd = steps_to(&curves[2].1).max(1.0);
    println!(
        "steps to reach GaLore-final acc {target:.3}: GaLore {s_galore}, SUMO-NS5 {s_ns5}, SUMO-SVD {s_svd}"
    );
    println!(
        "speedup SUMO-SVD vs GaLore: {:.2}x (paper reports ~1.6x); vs SUMO-NS5: {:.2}x",
        s_galore / s_svd,
        s_ns5 / s_svd
    );
    Ok(())
}
