//! Table 3 — pretraining LLaMA-family models on the C4-like corpus:
//! validation perplexity + memory for Full-Rank (Adam), GaLore, Low-Rank,
//! LoRA, ReLoRA, SUMO across model sizes. Paper sizes (60M–1B, H200) are
//! substituted by nano/micro/mini with token budgets scaling with size
//! (DESIGN.md §3); the comparative *shape* — SUMO ≤ GaLore ≤ Full-Rank ppl
//! at the smallest optimizer memory, Low-Rank far behind — is the target.

use sumo::bench::{scaled, TableWriter};
use sumo::config::{OptimCfg, OptimKind, Schedule, TrainCfg};
use sumo::coordinator::Coordinator;
use sumo::runtime::Runtime;
use sumo::train::Trainer;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_default_artifacts()?;
    // (preset, rank, steps): token budget grows with model size like the
    // paper's 1.1B→13.1B schedule.
    let sizes = [
        ("nano", 4usize, scaled(240)),
        ("micro", 8, scaled(320)),
        ("mini", 8, scaled(400)),
    ];
    let methods = [
        OptimKind::Adam, // Full-Rank row
        OptimKind::GaLore,
        OptimKind::LowRank,
        OptimKind::Lora,
        OptimKind::ReLora,
        OptimKind::Sumo,
    ];
    let mut table = TableWriter::new(
        "table3_pretrain",
        &[
            "Method",
            "nano ppl (mem)",
            "micro ppl (mem)",
            "mini ppl (mem)",
        ],
    );
    let mut rows: Vec<Vec<String>> = methods
        .iter()
        .map(|k| {
            let mut r = vec![String::new(); 4];
            r[0] = if *k == OptimKind::Adam {
                "Full-Rank".into()
            } else {
                k.paper_name().to_string()
            };
            r
        })
        .collect();
    for (col, (preset, rank, steps)) in sizes.iter().enumerate() {
        for (mi, &kind) in methods.iter().enumerate() {
            let lr = match kind {
                OptimKind::Adam | OptimKind::Lora | OptimKind::ReLora => 2e-3,
                OptimKind::LowRank | OptimKind::Sgd => 5e-2,
                _ => 2e-2,
            };
            let mut ocfg = OptimCfg::new(kind)
                .with_lr(lr)
                .with_rank(*rank)
                .with_update_freq(100);
            ocfg.relora_reset = (steps / 4).max(20);
            let tcfg = TrainCfg {
                steps: *steps,
                eval_batches: 8,
                log_every: 1_000_000,
                seed: 42,
                schedule: Schedule::CosineWarmup {
                    warmup: steps / 20 + 1,
                    min_ratio: 0.1,
                },
                ..TrainCfg::default()
            };
            let mut coord =
                Coordinator::native(&rt, &format!("{preset}_lm"), &ocfg, tcfg.seed, 1)?;
            let report = Trainer::new(tcfg).pretrain(&mut coord, None)?;
            rows[mi][col + 1] = format!(
                "{:.2} ({:.2}MB)",
                report.val_ppl,
                report.optimizer_state_bytes as f64 / 1e6
            );
            eprintln!(
                "{preset} {:<18} ppl {:.2} mem {:.2}MB ({} steps, {:.0}s)",
                kind.paper_name(),
                report.val_ppl,
                report.optimizer_state_bytes as f64 / 1e6,
                steps,
                report.seconds
            );
        }
    }
    for r in rows {
        table.row(&r);
    }
    table.finish().unwrap();
    println!("\ntoken budgets: {:?}", sizes.map(|(p, _, s)| (p, s * 8 * 64)));
    println!("paper-shape checks: SUMO ppl ≤ GaLore ≤ Full-Rank-adjacent; Low-Rank worst; SUMO min memory.");
    Ok(())
}
