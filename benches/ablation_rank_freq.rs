//! Ablation bench for SUMO's design choices (DESIGN.md §4):
//!   (a) projection rank r sweep,
//!   (b) subspace refresh frequency K sweep,
//!   (c) norm-growth limiter on/off,
//! all on the same synthetic-QNLI fine-tune used by Figure 2.

use sumo::bench::{scaled, TableWriter};
use sumo::config::{OptimCfg, OptimKind, Schedule, TrainCfg};
use sumo::coordinator::Coordinator;
use sumo::data::glue::GlueTask;
use sumo::runtime::Runtime;
use sumo::train::Trainer;

fn run(rt: &Runtime, ocfg: &OptimCfg, steps: usize) -> anyhow::Result<(f64, usize)> {
    let tcfg = TrainCfg {
        steps,
        eval_batches: 8,
        log_every: 1_000_000,
        seed: 13,
        schedule: Schedule::CosineWarmup {
            warmup: 5,
            min_ratio: 0.1,
        },
        ..TrainCfg::default()
    };
    let mut coord = Coordinator::native(rt, "micro_cls2", ocfg, tcfg.seed, 1)?;
    let task = GlueTask::by_name("QNLI", coord.runner.cfg.vocab, coord.runner.seq_len()).unwrap();
    let report = Trainer::new(tcfg).finetune_glue(&mut coord, &task)?;
    Ok((report.metric, report.optimizer_state_bytes))
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_default_artifacts()?;
    let steps = scaled(240);

    let mut t = TableWriter::new(
        "ablation_rank",
        &["rank r", "accuracy", "optim-state (KB)"],
    );
    for r in [2usize, 4, 8, 16, 32] {
        let ocfg = OptimCfg::new(OptimKind::Sumo).with_lr(0.02).with_rank(r).with_update_freq(50);
        let (acc, bytes) = run(&rt, &ocfg, steps)?;
        t.row(&[format!("{r}"), format!("{acc:.4}"), format!("{:.1}", bytes as f64 / 1e3)]);
        eprintln!("rank {r}: acc {acc:.4}");
    }
    t.finish().unwrap();

    let mut t = TableWriter::new("ablation_update_freq", &["K", "accuracy"]);
    for k in [10usize, 50, 200, 1_000_000] {
        let ocfg = OptimCfg::new(OptimKind::Sumo).with_lr(0.02).with_rank(8).with_update_freq(k);
        let (acc, _) = run(&rt, &ocfg, steps)?;
        let label = if k >= 1_000_000 { "fixed".to_string() } else { k.to_string() };
        t.row(&[label, format!("{acc:.4}")]);
        eprintln!("K {k}: acc {acc:.4}");
    }
    t.finish().unwrap();

    let mut t = TableWriter::new("ablation_limiter", &["limiter", "accuracy"]);
    for on in [true, false] {
        let mut ocfg = OptimCfg::new(OptimKind::Sumo).with_lr(0.02).with_rank(8).with_update_freq(50);
        ocfg.use_limiter = on;
        let (acc, _) = run(&rt, &ocfg, steps)?;
        t.row(&[format!("{}", if on { "on (γ=1.1)" } else { "off" }), format!("{acc:.4}")]);
        eprintln!("limiter {on}: acc {acc:.4}");
    }
    t.finish().unwrap();
    println!("\ndesign-choice ablations: moderate ranks + periodic refresh + limiter = the paper's defaults.");
    Ok(())
}
