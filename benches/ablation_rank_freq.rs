//! Ablation bench for SUMO's design choices (DESIGN.md §4):
//!   (a) projection rank r sweep,
//!   (b) subspace refresh frequency K sweep,
//!   (c) norm-growth limiter on/off,
//!   (d) fixed (r, K) grid vs the adaptive rank/refresh schedule
//!       (final loss, rank trace, total refresh FLOPs),
//! all on the same synthetic-QNLI fine-tune used by Figure 2.

use sumo::bench::{scaled, TableWriter};
use sumo::config::{OptimCfg, OptimKind, Schedule, TrainCfg};
use sumo::coordinator::{Coordinator, Engine};
use sumo::data::glue::GlueTask;
use sumo::runtime::Runtime;
use sumo::train::Trainer;

fn run(rt: &Runtime, ocfg: &OptimCfg, steps: usize) -> anyhow::Result<(f64, usize)> {
    let tcfg = TrainCfg {
        steps,
        eval_batches: 8,
        log_every: 1_000_000,
        seed: 13,
        schedule: Schedule::CosineWarmup {
            warmup: 5,
            min_ratio: 0.1,
        },
        ..TrainCfg::default()
    };
    let mut coord = Coordinator::native(rt, "micro_cls2", ocfg, tcfg.seed, 1)?;
    let task = GlueTask::by_name("QNLI", coord.runner.cfg.vocab, coord.runner.seq_len()).unwrap();
    let report = Trainer::new(tcfg).finetune_glue(&mut coord, &task)?;
    Ok((report.metric, report.optimizer_state_bytes))
}

/// Diagnostics of one fixed-or-adaptive run driven step by step (the
/// Trainer loop hides the optimizer, so the adaptive rows drive the
/// coordinator directly): mean training loss over the last quarter of the
/// run, the sampled mean-rank trace, total rank events, and the cumulative
/// Block-1 refresh FLOPs actually spent.
struct AdaptiveDiag {
    final_loss: f64,
    rank_trace: Vec<f32>,
    rank_events: usize,
    refresh_gflops: f64,
}

fn run_diag(rt: &Runtime, ocfg: &OptimCfg, steps: usize) -> anyhow::Result<AdaptiveDiag> {
    let mut coord = Coordinator::native(rt, "micro_cls2", ocfg, 13, 1)?;
    let task = GlueTask::by_name("QNLI", coord.runner.cfg.vocab, coord.runner.seq_len()).unwrap();
    let batch = coord.runner.batch;
    let sample_every = (steps / 6).max(1);
    let mut trace = Vec::new();
    let mut tail_losses = Vec::new();
    for step in 0..steps {
        let (toks, labels) = task.batch("train", (step * batch) as u64, batch);
        let metrics = coord.train_iteration_labeled(&toks, &labels, 1.0)?;
        if step >= steps - steps / 4 - 1 {
            tail_losses.push(metrics.loss as f64);
        }
        if step % sample_every == 0 || step + 1 == steps {
            if let Engine::Native(opt) = coord.engine_ref() {
                if let Some(s) = opt.as_sumo() {
                    trace.push(s.mean_rank());
                }
            }
        }
    }
    let (events, gflops) = match coord.engine_ref() {
        Engine::Native(opt) => opt
            .as_sumo()
            .map(|s| (s.rank_events(), s.refresh_flops_spent() as f64 / 1e9))
            .unwrap_or((0, 0.0)),
        _ => (0, 0.0),
    };
    Ok(AdaptiveDiag {
        final_loss: tail_losses.iter().sum::<f64>() / tail_losses.len().max(1) as f64,
        rank_trace: trace,
        rank_events: events,
        refresh_gflops: gflops,
    })
}

fn fmt_trace(trace: &[f32]) -> String {
    let parts: Vec<String> = trace.iter().map(|r| format!("{r:.1}")).collect();
    parts.join("→")
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_default_artifacts()?;
    let steps = scaled(240);

    let mut t = TableWriter::new(
        "ablation_rank",
        &["rank r", "accuracy", "optim-state (KB)"],
    );
    for r in [2usize, 4, 8, 16, 32] {
        let ocfg = OptimCfg::new(OptimKind::Sumo).with_lr(0.02).with_rank(r).with_update_freq(50);
        let (acc, bytes) = run(&rt, &ocfg, steps)?;
        t.row(&[format!("{r}"), format!("{acc:.4}"), format!("{:.1}", bytes as f64 / 1e3)]);
        eprintln!("rank {r}: acc {acc:.4}");
    }
    t.finish().unwrap();

    let mut t = TableWriter::new("ablation_update_freq", &["K", "accuracy"]);
    for k in [10usize, 50, 200, 1_000_000] {
        let ocfg = OptimCfg::new(OptimKind::Sumo).with_lr(0.02).with_rank(8).with_update_freq(k);
        let (acc, _) = run(&rt, &ocfg, steps)?;
        let label = if k >= 1_000_000 { "fixed".to_string() } else { k.to_string() };
        t.row(&[label, format!("{acc:.4}")]);
        eprintln!("K {k}: acc {acc:.4}");
    }
    t.finish().unwrap();

    let mut t = TableWriter::new("ablation_limiter", &["limiter", "accuracy"]);
    for on in [true, false] {
        let mut ocfg = OptimCfg::new(OptimKind::Sumo).with_lr(0.02).with_rank(8).with_update_freq(50);
        ocfg.use_limiter = on;
        let (acc, _) = run(&rt, &ocfg, steps)?;
        t.row(&[format!("{}", if on { "on (γ=1.1)" } else { "off" }), format!("{acc:.4}")]);
        eprintln!("limiter {on}: acc {acc:.4}");
    }
    t.finish().unwrap();

    // (d) Fixed grid vs the adaptive trajectory. Fixed rows re-run through
    // the same step-by-step harness so the loss column is comparable; the
    // adaptive row starts at r=8 inside a [2, 32] band with cost-aware
    // refresh scheduling. The rank trace shows the Lemma 3.1 response:
    // growth while gradients are broadband, collapse once the spectrum
    // concentrates.
    let mut t = TableWriter::new(
        "ablation_adaptive",
        &["config", "final loss", "rank trace", "rank events", "refresh GFLOPs"],
    );
    for r in [4usize, 8, 16] {
        let ocfg = OptimCfg::new(OptimKind::Sumo)
            .with_lr(0.02)
            .with_rank(r)
            .with_update_freq(50);
        let d = run_diag(&rt, &ocfg, steps)?;
        t.row(&[
            format!("fixed r{r} K50"),
            format!("{:.4}", d.final_loss),
            fmt_trace(&d.rank_trace),
            format!("{}", d.rank_events),
            format!("{:.3}", d.refresh_gflops),
        ]);
        eprintln!("fixed r{r}: loss {:.4}", d.final_loss);
    }
    for (label, freq) in [("adaptive r[2,32]", false), ("adaptive r[2,32]+K", true)] {
        let mut ocfg = OptimCfg::new(OptimKind::Sumo)
            .with_lr(0.02)
            .with_rank(8)
            .with_update_freq(50)
            .with_adaptive_rank(2, 32)
            .with_residual_band(0.01, 0.1);
        if freq {
            ocfg = ocfg.with_adaptive_freq();
        }
        let d = run_diag(&rt, &ocfg, steps)?;
        t.row(&[
            label.to_string(),
            format!("{:.4}", d.final_loss),
            fmt_trace(&d.rank_trace),
            format!("{}", d.rank_events),
            format!("{:.3}", d.refresh_gflops),
        ]);
        eprintln!("{label}: loss {:.4}, trace {}", d.final_loss, fmt_trace(&d.rank_trace));
    }
    t.finish().unwrap();

    println!("\ndesign-choice ablations: moderate ranks + periodic refresh + limiter = the paper's defaults.");
    println!(
        "adaptive rows: the rank trace tracks the residual signal (Lemma 3.1) and the \
         refresh-GFLOPs column prices the amortized Block-1 cost each schedule actually paid."
    );
    Ok(())
}
