//! Table 2 — GLUE fine-tuning comparison at ranks 4 and 8:
//! Full FT, LoRA, GaLore, SUMO (NS5), SUMO (SVD) across the 8 synthetic
//! GLUE tasks, reporting each task's paper metric plus measured
//! optimizer-state memory. The expected *shape*: SUMO(SVD) ≥ GaLore/LoRA
//! on most tasks at lower memory; the NS5 ablation trails SVD.
//!
//! Env: SUMO_BENCH_SCALE=full for the paper-size run; quick by default.
//! Pass `--ablation` via SUMO_TABLE2_ABLATION=1 to add limiter-off rows.

use sumo::bench::{scaled, TableWriter};
use sumo::config::{OptimCfg, OptimKind, Schedule, TrainCfg};
use sumo::coordinator::Coordinator;
use sumo::data::glue::{GlueMetric, GlueTask};
use sumo::runtime::Runtime;
use sumo::train::Trainer;

fn method_cfg(kind: OptimKind, rank: usize) -> OptimCfg {
    let lr = match kind {
        OptimKind::Adam => 2e-3,
        _ => 2e-2,
    };
    OptimCfg::new(kind)
        .with_lr(lr)
        .with_rank(rank)
        .with_update_freq(50)
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_default_artifacts()?;
    let steps = scaled(120);
    let ablation = std::env::var("SUMO_TABLE2_ABLATION").is_ok();
    let tasks = GlueTask::suite(512, 64); // micro preset vocab/seq
    let methods: Vec<(OptimKind, bool)> = vec![
        (OptimKind::Adam, true), // Full fine-tuning row
        (OptimKind::Lora, true),
        (OptimKind::GaLore, true),
        (OptimKind::SumoNs5, true),
        (OptimKind::Sumo, true),
    ];

    for rank in [4usize, 8] {
        let mut table = TableWriter::new(
            &format!("table2_glue_rank{rank}"),
            &[
                "Model", "Mem(KB)", "CoLA", "STS-B", "MRPC", "RTE", "SST2", "MNLI", "QNLI", "QQP",
            ],
        );
        for &(kind, _) in &methods {
            let mut row = vec![String::new(); 10];
            row[0] = if kind == OptimKind::Adam {
                "Full Fine-Tuning".to_string()
            } else {
                format!("{} (rank={rank})", kind.paper_name())
            };
            let mut mem = 0usize;
            for task in &tasks {
                let head = match task.metric {
                    GlueMetric::Pearson => "reg".to_string(),
                    _ => format!("cls{}", task.n_classes),
                };
                let ocfg = method_cfg(kind, rank);
                let tcfg = TrainCfg {
                    steps,
                    eval_batches: 6,
                    log_every: 1_000_000,
                    seed: 11,
                    schedule: Schedule::CosineWarmup {
                        warmup: 5,
                        min_ratio: 0.1,
                    },
                    ..TrainCfg::default()
                };
                let mut coord =
                    Coordinator::native(&rt, &format!("micro_{head}"), &ocfg, tcfg.seed, 1)?;
                let task = GlueTask::by_name(task.name, coord.runner.cfg.vocab, coord.runner.seq_len())
                    .unwrap();
                let report = Trainer::new(tcfg).finetune_glue(&mut coord, &task)?;
                mem = mem.max(report.optimizer_state_bytes);
                let col = match task.name {
                    "CoLA" => 2,
                    "STS-B" => 3,
                    "MRPC" => 4,
                    "RTE" => 5,
                    "SST2" => 6,
                    "MNLI" => 7,
                    "QNLI" => 8,
                    _ => 9,
                };
                row[col] = format!("{:.2}", 100.0 * report.metric);
                eprintln!(
                    "rank{rank} {:<22} {:<6} {}={:.4}",
                    kind.paper_name(),
                    task.name,
                    report.metric_name,
                    report.metric
                );
            }
            row[1] = format!("{:.0}", mem as f64 / 1e3);
            table.row(&row);
        }
        if ablation {
            // Ablation: SUMO without the norm-growth limiter (Block 3 off).
            let mut row = vec![String::new(); 10];
            row[0] = format!("SUMO (SVD, no limiter, rank={rank})");
            let mut mem = 0usize;
            for task in &tasks {
                let head = match task.metric {
                    GlueMetric::Pearson => "reg".to_string(),
                    _ => format!("cls{}", task.n_classes),
                };
                let mut ocfg = method_cfg(OptimKind::Sumo, rank);
                ocfg.use_limiter = false;
                let tcfg = TrainCfg {
                    steps,
                    eval_batches: 6,
                    log_every: 1_000_000,
                    seed: 11,
                    ..TrainCfg::default()
                };
                let mut coord =
                    Coordinator::native(&rt, &format!("micro_{head}"), &ocfg, tcfg.seed, 1)?;
                let task = GlueTask::by_name(task.name, coord.runner.cfg.vocab, coord.runner.seq_len())
                    .unwrap();
                let report = Trainer::new(tcfg).finetune_glue(&mut coord, &task)?;
                mem = mem.max(report.optimizer_state_bytes);
                let col = match task.name {
                    "CoLA" => 2,
                    "STS-B" => 3,
                    "MRPC" => 4,
                    "RTE" => 5,
                    "SST2" => 6,
                    "MNLI" => 7,
                    "QNLI" => 8,
                    _ => 9,
                };
                row[col] = format!("{:.2}", 100.0 * report.metric);
            }
            row[1] = format!("{:.0}", mem as f64 / 1e3);
            table.row(&row);
        }
        table.finish().unwrap();
    }
    println!("\npaper-shape checks: SUMO rows should use the least memory of the low-rank methods;");
    println!("SUMO (SVD) should match or beat GaLore/LoRA on most tasks; NS5 ablation trails SVD.");
    Ok(())
}
