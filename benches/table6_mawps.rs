//! Table 6 — MAWPS-style fine-tuning at ranks 32 and 128 (scaled to 8/32
//! on this testbed): wallclock, optimizer memory, accuracy for LoRA,
//! GaLore, SUMO (NS5), SUMO (SVD). Expected shape: LoRA fastest but least
//! accurate of the subspace methods; GaLore slowest; SUMO (SVD) most
//! accurate with less memory than GaLore and faster than GaLore.

use sumo::bench::{scaled, TableWriter};
use sumo::config::{OptimCfg, OptimKind, Schedule, TrainCfg};
use sumo::coordinator::Coordinator;
use sumo::data::glue::GlueMetric;
use sumo::runtime::Runtime;
use sumo::train::Trainer;
use sumo::util::Timer;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_default_artifacts()?;
    let steps = scaled(140);
    // The paper's MAWPS task is a short-answer accuracy benchmark; the
    // classification-style synthetic math task (2-class: which template
    // family solves the problem) exercises the same fine-tune path with a
    // clean accuracy metric at bench scale.
    let task = sumo::data::glue::GlueTask {
        name: "MAWPS-sim",
        n_classes: 2,
        metric: GlueMetric::Accuracy,
        signal: 0.09,
        sig_tokens: 8,
        seq_len: 64,
        vocab: 512,
        seed: 206,
    };
    for rank in [8usize, 32] {
        let mut table = TableWriter::new(
            &format!("table6_mawps_rank{rank}"),
            &["Method", "Rank", "Time(s)", "Optim-state (KB)", "Accuracy (%)"],
        );
        for kind in [
            OptimKind::Lora,
            OptimKind::GaLore,
            OptimKind::SumoNs5,
            OptimKind::Sumo,
        ] {
            let lr = if kind == OptimKind::Lora { 2e-3 } else { 2e-2 };
            let ocfg = OptimCfg::new(kind).with_lr(lr).with_rank(rank).with_update_freq(50);
            let tcfg = TrainCfg {
                steps,
                eval_batches: 6,
                log_every: 1_000_000,
                seed: 3,
                schedule: Schedule::CosineWarmup {
                    warmup: 5,
                    min_ratio: 0.1,
                },
                ..TrainCfg::default()
            };
            let mut coord = Coordinator::native(&rt, "micro_cls2", &ocfg, tcfg.seed, 1)?;
            let t = Timer::start();
            let report = Trainer::new(tcfg).finetune_glue(&mut coord, &task)?;
            let wall = t.secs();
            table.row(&[
                kind.paper_name().into(),
                format!("{rank}"),
                format!("{wall:.2}"),
                format!("{:.1}", report.optimizer_state_bytes as f64 / 1e3),
                format!("{:.2}", 100.0 * report.metric),
            ]);
            eprintln!(
                "rank{rank} {:<22} acc {:.3} mem {:.1}KB {:.1}s",
                kind.paper_name(),
                report.metric,
                report.optimizer_state_bytes as f64 / 1e3,
                wall
            );
        }
        table.finish().unwrap();
    }
    println!("\npaper-shape checks (Table 6): SUMO(SVD) most accurate; SUMO memory < GaLore;");
    println!("SUMO(SVD) step time < SUMO(NS5) at these ranks (exact SVD on the small side is cheaper).");
    Ok(())
}
