//! Lemma 3.1 — the first moment collapses toward rank one during training:
//! κ_M(t) = ‖M − P(1)M‖²_F / ‖M‖²_F ≤ O(C^{-t}) for reversible layers.
//!
//! The proof's mechanism: for a reversible layer the gradient takes the
//! form G = (1/N)Σᵢ(Aᵢ − Bᵢ W Cᵢ); under gradient descent the residual
//! decays eigen-mode by eigen-mode, so G(t) (and hence the EMA moment)
//! aligns with the slowest mode — becoming rank one at rate C =
//! ((1−ηλ₁)/(1−ηλ₂))⁻¹. We instantiate exactly that system (linear
//! regression layer, spread spectrum), run momentum accumulation, log
//! κ_M(t), and fit C.

use sumo::bench::TableWriter;
use sumo::linalg::norms::lowrank_residual;
use sumo::linalg::{matmul, matmul_a_bt, Mat};
use sumo::util::plot::ascii_plot;
use sumo::util::Rng;

fn main() {
    let mut rng = Rng::new(31);
    let (d_out, d_in, batch) = (12usize, 16usize, 64usize);
    // Inputs whose covariance has one well-separated slow mode: the
    // lemma's rate is C = (1−ηλ₁)/(1−ηλ₂) for the two smallest distinct
    // eigenvalues, so a clear λ₂ ≫ λ₁ gap exhibits the collapse sharply.
    let mut x = Mat::randn(d_in, batch, 1.0, &mut rng);
    for i in 0..d_in {
        let scale = if i + 1 == d_in { 0.22 } else { 1.0 - 0.02 * i as f32 };
        for v in x.row_mut(i) {
            *v *= scale;
        }
    }
    let w_true = Mat::randn(d_out, d_in, 0.8, &mut rng);
    let y = matmul(&w_true, &x);
    let mut w = Mat::randn(d_out, d_in, 0.2, &mut rng);
    let mut m = Mat::zeros(d_out, d_in);
    let beta = 0.9f32;
    // η chosen against λ_max of Σ = x xᵀ / batch for stable, fast decay.
    let sigma = {
        let mut s = matmul_a_bt(&x, &x);
        s.scale(1.0 / batch as f32);
        s
    };
    let lmax = sumo::linalg::spectral_norm(&sigma, 50);
    let lr = 0.9 / lmax;

    let mut t = TableWriter::new("lemma31_rank_decay", &["step", "kappa_M(t)"]);
    let mut series = Vec::new();
    for step in 0..400 {
        // Reversible-layer gradient: G = (W x − y) xᵀ / batch.
        let mut err = matmul(&w, &x);
        err.axpy(-1.0, &y);
        let mut g = matmul_a_bt(&err, &x);
        g.scale(1.0 / batch as f32);
        m.ema(beta, 1.0, &g); // the lemma's M = βM + G accumulation
        w.axpy(-lr, &g);
        if step % 20 == 0 || step == 399 {
            let k = lowrank_residual(&m, 1);
            t.row(&[format!("{step}"), format!("{k:.3e}")]);
            if k > 0.0 {
                series.push((step as f64, (k as f64).ln()));
            }
        }
    }
    t.finish().unwrap();
    println!(
        "{}",
        ascii_plot(&[("ln kappa_M(t)", &series)], 70, 12)
    );

    // Fit ln κ_M(t) = a − t·ln C over the decaying segment: from the peak
    // (early steps mix fast-mode transients into the fresh moment) to the
    // minimum (after which float round-off sets a plateau).
    let peak = series
        .iter()
        .enumerate()
        .max_by(|a, b| a.1 .1.partial_cmp(&b.1 .1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0);
    let trough = series
        .iter()
        .enumerate()
        .min_by(|a, b| a.1 .1.partial_cmp(&b.1 .1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(series.len() - 1);
    let tail: Vec<(f64, f64)> = series[peak..=trough.max(peak + 1)].to_vec();
    let n = tail.len() as f64;
    let sx: f64 = tail.iter().map(|p| p.0).sum();
    let sy: f64 = tail.iter().map(|p| p.1).sum();
    let sxx: f64 = tail.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = tail.iter().map(|p| p.0 * p.1).sum();
    let slope = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    let c = (-slope).exp();
    println!(
        "fitted κ_M(t) ≈ O(C^-t) with C = {c:.4} (paper: C > 1 ⇒ exponential rank-1 collapse: {})",
        if c > 1.0 { "CONFIRMED" } else { "NOT OBSERVED" }
    );
    println!(
        "κ_M: {:.4} at step {} → {:.3e} at step 399",
        series.first().unwrap().1.exp(),
        series.first().unwrap().0,
        series.last().unwrap().1.exp()
    );
}
