//! §Perf — hot-path microbenchmarks for the optimization pass:
//! L3 native kernels (matmul shapes of the SUMO step, orth, rSVD refresh),
//! the full native SUMO step (zero-alloc scratch engine), the threaded
//! multi-layer step dispatch, and end-to-end train iterations per preset.
//! Run before/after each optimization and record deltas in EXPERIMENTS.md
//! §Perf.
//!
//! Quick mode: `SUMO_BENCH_ITERS=1 cargo bench --bench perf_hotpath` caps
//! per-kernel timing iterations (CI's bench-smoke job uses this). Output:
//! bench_out/perf_hotpath.{md,csv} plus `BENCH_perf_hotpath.json` in the
//! working directory — the artifact CI uploads so the perf trajectory
//! accumulates across PRs.

use sumo::bench::{bench_iters, TableWriter};
use sumo::cluster::codec::{decode_mats, encode_mats, GradCodec};
use sumo::cluster::messages::{decode, encode, Msg};
use sumo::cluster::model_layers;
use sumo::cluster::task::{init_weights, SyntheticTask};
use sumo::config::{ModelCfg, OptimCfg, OptimKind};
use sumo::coordinator::allreduce_mean;
use sumo::coordinator::Coordinator;
use sumo::data::{Batcher, SyntheticCorpus};
use sumo::linalg::{
    gemm_into, matmul, matmul_a_bt, matmul_at_b, newton_schulz5, orth_svd, orth_svd_batched_into,
    orth_svd_into, randomized_range, BatchOrthScratch, GemmOp, GemmScratch, Mat, OrthScratch,
    RsvdOpts,
};
use sumo::model::ParamStore;
use sumo::runtime::Runtime;
use sumo::util::threadpool;
use sumo::util::timer::{time_fn, Stats};
use sumo::util::Rng;

use std::sync::atomic::{AtomicU64, Ordering};

/// Emit one timing row with *numeric* cells so the JSON artifact is
/// machine-readable (mean/ci in ms as numbers, not "x ± y ms" strings).
fn timing_row(t: &mut TableWriter, kernel: &str, shape: &str, s: &Stats) {
    t.row(&[
        kernel.to_string(),
        shape.to_string(),
        format!("{:.4}", s.mean() * 1e3),
        format!("{:.4}", s.ci95() * 1e3),
        format!("{}", s.n),
    ]);
}

fn main() -> anyhow::Result<()> {
    let mut t = TableWriter::new(
        "perf_hotpath",
        &["kernel", "shape", "ms_mean", "ms_ci95", "n"],
    );
    let mut rng = Rng::new(99);

    // L3 linalg kernels at the shapes the small-preset SUMO step uses.
    for &(m, k, n, label) in &[
        (2048usize, 256usize, 16usize, "proj GᵀQ-ish"),
        (256, 2048, 16, "proj (wide)"),
        (2048, 16, 256, "back-proj"),
        (512, 512, 512, "square matmul"),
    ] {
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let s = time_fn(1, bench_iters(5), || {
            let _ = matmul(&a, &b);
        });
        timing_row(&mut t, &format!("matmul {label}"), &format!("{m}x{k}x{n}"), &s);
    }
    {
        let a = Mat::randn(2048, 256, 1.0, &mut rng);
        let q = Mat::randn(2048, 16, 1.0, &mut rng);
        let s = time_fn(1, bench_iters(5), || {
            let _ = matmul_at_b(&q, &a);
        });
        timing_row(&mut t, "matmul_at_b (QᵀG)", "16x2048x256", &s);
    }
    // Third orientation at the step shapes: the right-side back-projection
    // O·Qᵀ (2048×16 · (256×16)ᵀ), previously an unbenched serial f64 loop.
    {
        let o = Mat::randn(2048, 16, 1.0, &mut rng);
        let q = Mat::randn(256, 16, 1.0, &mut rng);
        let s = time_fn(1, bench_iters(5), || {
            let _ = matmul_a_bt(&o, &q);
        });
        timing_row(&mut t, "matmul_a_bt (OQᵀ back-proj)", "2048x16x256", &s);
    }
    // Fused Block-4 epilogue: W ← β·W + α·(Q·O) in one GEMM pass vs the
    // unfused materialize-then-scale-then-axpy sequence it replaced.
    {
        let q = Mat::randn(2048, 16, 1.0, &mut rng);
        let o = Mat::randn(16, 256, 1.0, &mut rng);
        let mut w = Mat::randn(2048, 256, 0.1, &mut rng);
        let mut full = Mat::zeros(2048, 256);
        let mut ws = GemmScratch::new();
        let (alpha, beta) = (-0.02f32, 0.999f32);
        let s = time_fn(1, bench_iters(5), || {
            sumo::linalg::matmul_into(&q, &o, &mut full);
            w.scale(beta);
            w.axpy(alpha, &full);
        });
        timing_row(&mut t, "block4 apply (unfused)", "2048x256 r16", &s);
        let s = time_fn(1, bench_iters(5), || {
            gemm_into(GemmOp::Nn, alpha, &q, &o, beta, &mut w, &mut ws);
        });
        timing_row(&mut t, "fused block4 epilogue", "2048x256 r16", &s);
    }
    for &r in &[4usize, 16, 64] {
        let m = Mat::randn(r, 2048, 1.0, &mut rng);
        let s = time_fn(1, bench_iters(8), || {
            let _ = orth_svd(&m);
        });
        timing_row(&mut t, "orth_svd", &format!("{r}x2048"), &s);
        let s = time_fn(1, bench_iters(8), || {
            let _ = newton_schulz5(&m, 5);
        });
        timing_row(&mut t, "ns5", &format!("{r}x2048"), &s);
    }
    {
        let g = Mat::randn(2048, 256, 1.0, &mut rng);
        let s = time_fn(1, bench_iters(3), || {
            let mut r2 = Rng::new(5);
            let _ = randomized_range(&g, 16, RsvdOpts::default(), &mut r2);
        });
        timing_row(&mut t, "rsvd range (refresh)", "2048x256 r16", &s);
    }

    // Cluster wire codec at real LM gradient shapes: one `Grads` frame
    // carrying a full nano gradient set — the payload every worker sends
    // each round — through each negotiable codec, encoded and decoded back.
    // Gradient-scale magnitudes (σ=1e-3) so the lossless byte planes see
    // the redundancy they were designed for; the printed byte counts are
    // the bytes-on-wire ratios recorded in EXPERIMENTS.md §Perf.
    {
        let mcfg = ModelCfg::preset("nano").unwrap();
        let layers = model_layers(&mcfg);
        let mats: Vec<Mat> = layers
            .iter()
            .map(|l| Mat::randn(l.rows, l.cols, 1e-3, &mut rng))
            .collect();
        let nlayers = layers.len();
        let mut wire = Vec::new();
        for (codec, row) in [
            (GradCodec::Raw, "grads codec (encode+decode)"),
            (GradCodec::Lossless, "grads codec (lossless enc+dec)"),
            (GradCodec::Q8Det, "grads codec (q8 enc+dec)"),
        ] {
            let payload = encode_mats(codec, &mats);
            wire.push((codec, payload.len()));
            let msg = Msg::Grads { step: 7, shard: 0, loss: 3.25, grads: payload };
            let s = time_fn(1, bench_iters(8), || {
                let frame = encode(&msg);
                let Msg::Grads { grads, .. } = decode(&frame).unwrap() else {
                    unreachable!()
                };
                let _ = decode_mats(codec, &grads).unwrap();
            });
            timing_row(&mut t, row, &format!("nano {nlayers}T"), &s);
        }
        let raw_bytes = wire[0].1 as f64;
        for (codec, bytes) in &wire {
            println!(
                "grads payload {:?}: {} B ({:.2}x vs raw)",
                codec,
                bytes,
                raw_bytes / *bytes as f64
            );
        }
    }

    // Failover round: a worker dies owning 1 of 4 shards — a survivor
    // recomputes the lost shard's gradients from its replicated weights and
    // the reduction runs over all 4 shard sets again. This is the marginal
    // cost a mid-round kill adds to one training round at nano shapes; the
    // perf-diff gate keeps takeover from regressing into a full-round stall.
    {
        let mcfg = ModelCfg::preset("nano").unwrap();
        let layers = model_layers(&mcfg);
        let task = SyntheticTask::new(42, 0.01, &layers);
        let weights = init_weights(42, &layers);
        let shard_sets: Vec<Vec<Mat>> = (0..4u64)
            .map(|s| task.shard_grads(&weights, 3, s).1)
            .collect();
        let s = time_fn(1, bench_iters(8), || {
            let (_, recomputed) = task.shard_grads(&weights, 3, 1);
            let mut sets = shard_sets.clone();
            sets[1] = recomputed;
            let _ = allreduce_mean(&mut sets);
        });
        timing_row(&mut t, "failover round (1 lost shard)", "nano 4-shard", &s);
    }

    // Invariant linter over the full crate source: the CI gate's cost.
    // Staying sub-100ms keeps `sumo lint --deny all` cheap enough for a
    // pre-commit hook; the perf-diff gate catches a rule turning
    // accidentally quadratic in file size.
    {
        let src_root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let report = sumo::analysis::lint_tree(&src_root)?;
        let files = report.files;
        let s = time_fn(1, bench_iters(5), || {
            let _ = sumo::analysis::lint_tree(&src_root).unwrap();
        });
        timing_row(&mut t, "lint full-crate scan", "rust/src", &s);
        println!("lint scanned {files} files");
    }

    // Dispatch overhead: the same worker-count parallel-for over trivial
    // tasks through (a) per-call scoped spawn/join — what every pool
    // dispatch paid before resident workers — and (b) the resident-worker
    // barrier. Tiny per-task work isolates the fixed cost the three-phase
    // grouped step pays at every phase boundary; the perf-diff gate tracks
    // the win across PRs.
    {
        let pool = threadpool::global();
        let n_tasks = 16usize;
        let cells: Vec<AtomicU64> = (0..n_tasks).map(|_| AtomicU64::new(0)).collect();
        let workers = pool.size().min(n_tasks);
        let chunk = n_tasks.div_ceil(workers);
        let s = time_fn(2, bench_iters(30), || {
            std::thread::scope(|scope| {
                for w in 0..workers {
                    let lo = w * chunk;
                    let hi = ((w + 1) * chunk).min(n_tasks);
                    if lo >= hi {
                        break;
                    }
                    let cells = &cells;
                    scope.spawn(move || {
                        for cell in &cells[lo..hi] {
                            cell.fetch_add(1, Ordering::Relaxed);
                        }
                    });
                }
            });
        });
        timing_row(&mut t, "pool dispatch (scoped)", &format!("{n_tasks} tasks"), &s);
        let s = time_fn(2, bench_iters(30), || {
            pool.par_for(n_tasks, |i| {
                cells[i].fetch_add(1, Ordering::Relaxed);
            });
        });
        timing_row(&mut t, "pool dispatch (resident)", &format!("{n_tasks} tasks"), &s);
    }

    // Batched orthogonalization: N stacked moments of one shape class
    // through one masked Jacobi sweep schedule (pool-chunked batch axis) vs
    // the per-layer loop — the grouped-step (phase 2) kernel. Acceptance:
    // ≥1.5x throughput for ≥16 stacked rank-4/8 moments.
    {
        let pool = threadpool::global();
        for &(r, nlayers) in &[(4usize, 16usize), (8, 16), (16, 12)] {
            let ms: Vec<Mat> = (0..nlayers)
                .map(|_| Mat::randn(r, 2048, 1.0, &mut rng))
                .collect();
            let mut outs: Vec<Mat> = ms.iter().map(|_| Mat::zeros(r, 2048)).collect();
            let mut per_ws: Vec<OrthScratch> =
                (0..nlayers).map(|_| OrthScratch::new(r, 2048)).collect();
            let shape = format!("{nlayers}x {r}x2048");
            let s = time_fn(1, bench_iters(8), || {
                for ((m, o), ws) in ms.iter().zip(outs.iter_mut()).zip(per_ws.iter_mut()) {
                    orth_svd_into(m, o, ws);
                }
            });
            timing_row(&mut t, "orth_svd loop", &shape, &s);
            let mut bws = BatchOrthScratch::new(nlayers, r, 2048);
            let s = time_fn(1, bench_iters(8), || {
                let ins: Vec<&Mat> = ms.iter().collect();
                let mut out_refs: Vec<&mut Mat> = outs.iter_mut().collect();
                orth_svd_batched_into(&ins, &mut out_refs, &mut bws, Some(pool));
            });
            // Row names stay core-count-free so the perf-diff gate keys
            // (kernel, shape) match across runners with different pools.
            timing_row(&mut t, "orth_svd_batched", &shape, &s);
        }
    }

    // Native SUMO step on the biggest layer shape (zero-alloc steady state).
    {
        let cfg = OptimCfg::new(OptimKind::Sumo).with_rank(16).with_update_freq(100);
        let mut opt = sumo::optim::build(&cfg, &[(2048, 256)], &[true], 1);
        let mut w = Mat::randn(2048, 256, 0.1, &mut rng);
        let g = Mat::randn(2048, 256, 1.0, &mut rng);
        opt.step(0, &mut w, &g, 1.0); // allocate states + first refresh
        let s = time_fn(2, bench_iters(10), || {
            opt.step(0, &mut w, &g, 1.0);
            opt.end_step();
        });
        timing_row(&mut t, "native SUMO step", "2048x256 r16", &s);
    }

    // Adaptive rank event: a step whose refresh measures the residual,
    // grows the rank (8 → 16), transports the moment and regrows the step
    // scratch. Each timed iteration consumes its own pre-warmed optimizer
    // positioned one step before its first grow event, so every sample
    // crosses a rank boundary (a saturated optimizer would measure the
    // plain refresh path instead).
    {
        let iters = bench_iters(5).max(1);
        let g = Mat::randn(512, 64, 1.0, &mut rng);
        let mut w = Mat::randn(512, 64, 0.1, &mut rng);
        let mut cfg = OptimCfg::new(OptimKind::Sumo)
            .with_rank(8)
            .with_update_freq(1)
            .with_adaptive_rank(4, 16)
            .with_residual_band(0.0, 0.0);
        cfg.rank_step = 8;
        let mut opts: Vec<_> = (0..iters)
            .map(|_| {
                let mut o = sumo::optim::build(&cfg, &[(512, 64)], &[true], 1);
                o.step(0, &mut w, &g, 1.0); // warm-up refresh at rank 8
                o.end_step();
                o
            })
            .collect();
        let mut k = 0usize;
        let s = time_fn(0, iters, || {
            opts[k].step(0, &mut w, &g, 1.0);
            opts[k].end_step();
            k += 1;
        });
        assert!(opts.iter().all(|o| o.as_sumo().unwrap().rank_events() == 1));
        timing_row(&mut t, "rank-event step (adaptive)", "512x64 r8→16", &s);
    }

    // Multi-layer step engine: serial loop vs ThreadPool::par_for dispatch
    // over 12 independent layers (the trainer's per-iteration shape).
    {
        let shapes: Vec<(usize, usize)> = (0..12).map(|_| (512usize, 256usize)).collect();
        let projected = vec![true; shapes.len()];
        let cfg = OptimCfg::new(OptimKind::Sumo).with_rank(16).with_update_freq(10_000);
        let grads: Vec<Mat> = shapes.iter().map(|&(m, n)| Mat::randn(m, n, 1.0, &mut rng)).collect();
        let mut weights: Vec<Mat> = shapes.iter().map(|&(m, n)| Mat::randn(m, n, 0.1, &mut rng)).collect();

        let mut serial = sumo::optim::build(&cfg, &shapes, &projected, 7);
        // Warm up states, then time the serial per-layer loop.
        for (i, (w, g)) in weights.iter_mut().zip(&grads).enumerate() {
            serial.step(i, w, g, 1.0);
        }
        let s = time_fn(1, bench_iters(6), || {
            for (i, (w, g)) in weights.iter_mut().zip(&grads).enumerate() {
                serial.step(i, w, g, 1.0);
            }
            serial.end_step();
        });
        timing_row(&mut t, "step engine (serial)", "12x 512x256 r16", &s);

        let pool = threadpool::global();
        let mut par = sumo::optim::build(&cfg, &shapes, &projected, 7);
        {
            let mut refs: Vec<&mut Mat> = weights.iter_mut().collect();
            par.step_parallel(pool, &mut refs, &grads, 1.0); // warm up
        }
        let s = time_fn(1, bench_iters(6), || {
            let mut refs: Vec<&mut Mat> = weights.iter_mut().collect();
            par.step_parallel(pool, &mut refs, &grads, 1.0);
            par.end_step();
        });
        timing_row(&mut t, "step engine (par)", "12x 512x256 r16", &s);
    }

    // Grouped three-phase step per model preset: real layer-shape mixes
    // (many layers per moment shape class), serial per-layer loop vs the
    // batched-orthogonalization dispatch.
    for preset in ["nano", "micro", "small"] {
        let Some(mcfg) = ModelCfg::preset(preset) else {
            continue;
        };
        let params = ParamStore::init(&mcfg, 1);
        let shapes = params.shapes();
        let projected = params.projected_mask();
        let rank = if preset == "small" { 16 } else { 4 };
        let cfg = OptimCfg::new(OptimKind::Sumo).with_rank(rank).with_update_freq(10_000);
        let grads: Vec<Mat> = shapes.iter().map(|&(m, n)| Mat::randn(m, n, 1.0, &mut rng)).collect();
        let mut weights: Vec<Mat> = shapes.iter().map(|&(m, n)| Mat::randn(m, n, 0.1, &mut rng)).collect();
        let nlayers = shapes.len();

        let mut serial = sumo::optim::build(&cfg, &shapes, &projected, 9);
        for (i, (w, g)) in weights.iter_mut().zip(&grads).enumerate() {
            serial.step(i, w, g, 1.0);
        }
        let s = time_fn(1, bench_iters(5), || {
            for (i, (w, g)) in weights.iter_mut().zip(&grads).enumerate() {
                serial.step(i, w, g, 1.0);
            }
            serial.end_step();
        });
        timing_row(&mut t, "grouped step (serial)", &format!("{preset} {nlayers}L r{rank}"), &s);

        let pool = threadpool::global();
        let mut par = sumo::optim::build(&cfg, &shapes, &projected, 9);
        {
            let mut refs: Vec<&mut Mat> = weights.iter_mut().collect();
            par.step_parallel(pool, &mut refs, &grads, 1.0);
        }
        let s = time_fn(1, bench_iters(5), || {
            let mut refs: Vec<&mut Mat> = weights.iter_mut().collect();
            par.step_parallel(pool, &mut refs, &grads, 1.0);
            par.end_step();
        });
        timing_row(
            &mut t,
            "grouped step (3-phase)",
            &format!("{preset} {nlayers}L r{rank}"),
            &s,
        );
    }

    // End-to-end iterations (fwd/bwd via PJRT + optimizer).
    if let Ok(rt) = Runtime::from_default_artifacts() {
        for preset in ["nano", "micro", "small"] {
            let cfg = OptimCfg::new(OptimKind::Sumo)
                .with_lr(0.02)
                .with_rank(if preset == "small" { 16 } else { 4 })
                .with_update_freq(100);
            let model = format!("{preset}_lm");
            let mut coord = Coordinator::native(&rt, &model, &cfg, 1, 1)?;
            let corpus = SyntheticCorpus::new(coord.runner.cfg.vocab, 1);
            let mut batcher = Batcher::new(corpus, coord.runner.batch, coord.runner.seq_len());
            let warm = batcher.next();
            coord.train_iteration(&warm, 1.0)?; // compile
            let batches: Vec<_> = (0..4).map(|_| batcher.next()).collect();
            let mut i = 0;
            let s = time_fn(0, bench_iters(4), || {
                let b = batches[i % batches.len()].clone();
                coord.train_iteration(&b, 1.0).unwrap();
                i += 1;
            });
            timing_row(&mut t, "e2e train step (native)", &model, &s);
            // HLO engine for presets with artifacts.
            if sumo::runtime::HloSumo::new(&rt, &coord.params, &cfg, 1).is_ok() {
                let mut hcoord = Coordinator::hlo_sumo(&rt, &model, &cfg, 1)?;
                hcoord.train_iteration(&warm, 1.0)?;
                let mut j = 0;
                let batches2: Vec<_> = (0..4).map(|_| batcher.next()).collect();
                let s = time_fn(0, bench_iters(4), || {
                    let b = batches2[j % batches2.len()].clone();
                    hcoord.train_iteration(&b, 1.0).unwrap();
                    j += 1;
                });
                timing_row(&mut t, "e2e train step (hlo sumo)", &model, &s);
            }
        }
    } else {
        eprintln!("artifacts absent: skipping e2e rows (kernel rows above are complete)");
    }
    t.finish().unwrap();
    // Machine-readable artifact for CI's perf-trajectory upload. Cargo runs
    // bench binaries with CWD = the package root (rust/), so CI points
    // SUMO_BENCH_JSON at the workspace root for a stable upload path.
    let json_path = std::env::var("SUMO_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_perf_hotpath.json".to_string());
    t.write_json(&json_path).unwrap();
    println!("wrote {json_path}");
    Ok(())
}
