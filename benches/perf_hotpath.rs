//! §Perf — hot-path microbenchmarks for the optimization pass:
//! L3 native kernels (matmul shapes of the SUMO step, orth, rSVD refresh),
//! the full native SUMO step, the HLO SUMO step, and end-to-end train
//! iterations per preset. Run before/after each optimization and record
//! deltas in EXPERIMENTS.md §Perf.

use sumo::bench::{fmt_ms, TableWriter};
use sumo::config::{OptimCfg, OptimKind, TrainCfg};
use sumo::coordinator::Coordinator;
use sumo::data::{Batcher, SyntheticCorpus};
use sumo::linalg::{matmul, matmul_at_b, newton_schulz5, orth_svd, randomized_range, Mat, RsvdOpts};
use sumo::runtime::Runtime;
use sumo::util::timer::time_fn;
use sumo::util::Rng;

fn main() -> anyhow::Result<()> {
    let mut t = TableWriter::new("perf_hotpath", &["kernel", "shape", "time"]);
    let mut rng = Rng::new(99);

    // L3 linalg kernels at the shapes the small-preset SUMO step uses.
    for &(m, k, n, label) in &[
        (2048usize, 256usize, 16usize, "proj GᵀQ-ish"),
        (256, 2048, 16, "proj (wide)"),
        (2048, 16, 256, "back-proj"),
        (512, 512, 512, "square matmul"),
    ] {
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let s = time_fn(1, 5, || {
            let _ = matmul(&a, &b);
        });
        t.row(&[format!("matmul {label}"), format!("{m}x{k}x{n}"), fmt_ms(&s)]);
    }
    {
        let a = Mat::randn(2048, 256, 1.0, &mut rng);
        let q = Mat::randn(2048, 16, 1.0, &mut rng);
        let s = time_fn(1, 5, || {
            let _ = matmul_at_b(&q, &a);
        });
        t.row(&["matmul_at_b (QᵀG)".into(), "16x2048x256".into(), fmt_ms(&s)]);
    }
    for &r in &[4usize, 16, 64] {
        let m = Mat::randn(r, 2048, 1.0, &mut rng);
        let s = time_fn(1, 8, || {
            let _ = orth_svd(&m);
        });
        t.row(&[format!("orth_svd"), format!("{r}x2048"), fmt_ms(&s)]);
        let s = time_fn(1, 8, || {
            let _ = newton_schulz5(&m, 5);
        });
        t.row(&[format!("ns5"), format!("{r}x2048"), fmt_ms(&s)]);
    }
    {
        let g = Mat::randn(2048, 256, 1.0, &mut rng);
        let s = time_fn(1, 3, || {
            let mut r2 = Rng::new(5);
            let _ = randomized_range(&g, 16, RsvdOpts::default(), &mut r2);
        });
        t.row(&["rsvd range (refresh)".into(), "2048x256 r16".into(), fmt_ms(&s)]);
    }

    // Native SUMO step on the biggest layer shape.
    {
        let cfg = OptimCfg::new(OptimKind::Sumo).with_rank(16).with_update_freq(100);
        let mut opt = sumo::optim::build(&cfg, &[(2048, 256)], &[true], 1);
        let mut w = Mat::randn(2048, 256, 0.1, &mut rng);
        let g = Mat::randn(2048, 256, 1.0, &mut rng);
        opt.step(0, &mut w, &g, 1.0); // allocate states + first refresh
        let s = time_fn(2, 10, || {
            opt.step(0, &mut w, &g, 1.0);
            opt.end_step();
        });
        t.row(&["native SUMO step".into(), "2048x256 r16".into(), fmt_ms(&s)]);
    }

    // End-to-end iterations (fwd/bwd via PJRT + optimizer).
    if let Ok(rt) = Runtime::from_default_artifacts() {
        for preset in ["nano", "micro", "small"] {
            let cfg = OptimCfg::new(OptimKind::Sumo)
                .with_lr(0.02)
                .with_rank(if preset == "small" { 16 } else { 4 })
                .with_update_freq(100);
            let model = format!("{preset}_lm");
            let mut coord = Coordinator::native(&rt, &model, &cfg, 1, 1)?;
            let corpus = SyntheticCorpus::new(coord.runner.cfg.vocab, 1);
            let mut batcher = Batcher::new(corpus, coord.runner.batch, coord.runner.seq_len());
            let warm = batcher.next();
            coord.train_iteration(&warm, 1.0)?; // compile
            let mut batches: Vec<_> = (0..4).map(|_| batcher.next()).collect();
            let mut i = 0;
            let s = time_fn(0, 4, || {
                let b = batches[i % batches.len()].clone();
                coord.train_iteration(&b, 1.0).unwrap();
                i += 1;
            });
            let _ = &mut batches;
            t.row(&[format!("e2e train step (native)"), model.clone(), fmt_ms(&s)]);
            // HLO engine for presets with artifacts.
            if sumo::runtime::HloSumo::new(&rt, &coord.params, &cfg, 1).is_ok() {
                let mut hcoord = Coordinator::hlo_sumo(&rt, &model, &cfg, 1)?;
                hcoord.train_iteration(&warm, 1.0)?;
                let mut j = 0;
                let batches2: Vec<_> = (0..4).map(|_| batcher.next()).collect();
                let s = time_fn(0, 4, || {
                    let b = batches2[j % batches2.len()].clone();
                    hcoord.train_iteration(&b, 1.0).unwrap();
                    j += 1;
                });
                t.row(&["e2e train step (hlo sumo)".into(), model.clone(), fmt_ms(&s)]);
            }
        }
    }
    t.finish().unwrap();
    Ok(())
}
