//! Figure 1 — ill-conditioning of the first moment during GaLore-style
//! fine-tuning of a transformer on the synthetic RTE task:
//!   (a) condition number of M Mᵀ vs training step (red line at 10),
//!   (b) the moment's singular-value decay at step ~100.
//!
//! Runs the real stack (PJRT fwd/bwd + native GaLore) and logs the
//! diagnostics the `optim::galore` module exposes for exactly this figure.

use sumo::bench::{scaled, TableWriter};
use sumo::config::{OptimCfg, OptimKind, Schedule, TrainCfg};
use sumo::coordinator::{Coordinator, Engine};
use sumo::data::glue::GlueTask;
use sumo::runtime::Runtime;
use sumo::util::plot::ascii_plot;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_default_artifacts()?;
    let steps = scaled(120);
    let ocfg = OptimCfg::new(OptimKind::GaLore)
        .with_lr(0.02)
        .with_rank(16)
        .with_update_freq(1_000_000); // fixed subspace, as in the figure
    let mut coord = Coordinator::native(&rt, "micro_cls2", &ocfg, 7, 1)?;
    let task = GlueTask::by_name("RTE", coord.runner.cfg.vocab, coord.runner.seq_len()).unwrap();
    let tcfg = TrainCfg {
        steps,
        schedule: Schedule::Constant,
        ..TrainCfg::default()
    };

    // Watch the largest projected layer (wq of layer 0 = index of "l0.wq").
    let watch = coord
        .params
        .tensors
        .iter()
        .position(|(n, _)| n == "l0.wq")
        .unwrap();

    let mut t = TableWriter::new("fig1a_condition_number", &["step", "cond(MMt)"]);
    let mut series = Vec::new();
    for step in 0..tcfg.steps {
        let batch = coord.runner.batch;
        let (toks, labels) = task.batch("train", (step * batch) as u64, batch);
        coord.train_iteration_labeled(&toks, &labels, 1.0)?;
        if step % 5 == 0 || step + 1 == tcfg.steps {
            if let Engine::Native(opt) = coord.engine_ref() {
                if let Some(g) = opt.as_galore() {
                    if let Some(c) = g.moment_cond(watch) {
                        t.row(&[format!("{step}"), format!("{c:.2}")]);
                        series.push((step as f64, (c as f64).log10()));
                    }
                }
            }
        }
    }
    t.finish().unwrap();
    println!(
        "{}",
        ascii_plot(&[("log10 cond(MMt)", &series)], 70, 12)
    );
    let above10 = series.iter().filter(|(_, c)| *c > 1.0).count();
    println!(
        "paper check (Fig 1a): condition number exceeds 10 in {above10}/{} samples",
        series.len()
    );

    // (b) singular-value decay at the last logged step.
    if let Engine::Native(opt) = coord.engine_ref() {
        if let Some(g) = opt.as_galore() {
            if let Some(spec) = g.moment_spectrum(watch) {
                let mut t = TableWriter::new("fig1b_spectrum", &["index", "sigma_i/sigma_1"]);
                let s1 = spec[0].max(1e-30);
                let pts: Vec<(f64, f64)> = spec
                    .iter()
                    .enumerate()
                    .map(|(i, &s)| {
                        t.row(&[format!("{i}"), format!("{:.5}", s / s1)]);
                        (i as f64, (s / s1) as f64)
                    })
                    .collect();
                t.finish().unwrap();
                println!("{}", ascii_plot(&[("sigma_i/sigma_1", &pts)], 60, 10));
                let tail = pts.last().unwrap().1;
                println!(
                    "paper check (Fig 1b): gradual spectral decay, σ_r/σ_1 = {tail:.4} (≫ machine eps ⇒ ill-conditioned Gram)"
                );
            }
        }
    }
    Ok(())
}
