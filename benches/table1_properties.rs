//! Table 1 — properties of SUMO vs Adam / Shampoo / SOAP / GaLore:
//! computation (FLOPs/step), optimizer-state memory, subspace-awareness,
//! orthogonalization. Analytic formulas (pinned to the paper's rows by unit
//! tests) next to *measured* state bytes from the live optimizers, plus a
//! measured per-step wallclock column on this testbed.

use sumo::bench::TableWriter;
use sumo::config::{OptimCfg, OptimKind};
use sumo::linalg::Mat;
use sumo::optim::{self, flops_per_step, state_memory_floats};
use sumo::util::timer::time_fn;
use sumo::util::Rng;

fn measured_state_and_time(kind: OptimKind, m: usize, n: usize, r: usize) -> (usize, f64) {
    let cfg = OptimCfg::new(kind).with_rank(r).with_update_freq(200);
    let mut opt = optim::build(&cfg, &[(m, n)], &[true], 1);
    let mut rng = Rng::new(2);
    let mut w = Mat::randn(m, n, 0.1, &mut rng);
    let g = Mat::randn(m, n, 1.0, &mut rng);
    // Warm (allocates states), then time steady-state steps.
    opt.step(0, &mut w, &g, 1.0);
    opt.end_step();
    let stats = time_fn(1, 3, || {
        opt.step(0, &mut w, &g, 1.0);
        opt.end_step();
    });
    (opt.state_bytes(), stats.mean() * 1e3)
}

fn main() {
    let (m, n, r, k) = (1024usize, 256usize, 16usize, 200usize);
    println!("Table 1: W in R^{m}x{n}, rank r={r}, subspace update K={k}\n");
    let mut t = TableWriter::new(
        "table1_properties",
        &[
            "Method",
            "Computation (FLOPs/step, analytic)",
            "Optim-state floats (analytic)",
            "Optim-state bytes (measured)",
            "ms/step (measured)",
            "Subspace-aware",
            "Orthogonalization",
        ],
    );
    let rows = [
        (OptimKind::Sumo, "yes", "yes (exact SVD)"),
        (OptimKind::SumoNs5, "yes", "yes (NS5)"),
        (OptimKind::GaLore, "yes", "no"),
        (OptimKind::Adam, "no", "no"),
        (OptimKind::Muon, "no", "yes (NS5)"),
        (OptimKind::Osgdm, "no", "yes (exact SVD)"),
        (OptimKind::LowRank, "fixed", "no"),
        (OptimKind::Lora, "fixed", "no"),
    ];
    for (kind, sub, orth) in rows {
        let (bytes, ms) = measured_state_and_time(kind, m, n, r);
        t.row(&[
            kind.paper_name().to_string(),
            format!("{:.2e}", flops_per_step(kind, m, n, r, k) as f64),
            format!("{}", state_memory_floats(kind, m, n, r)),
            format!("{bytes}"),
            format!("{ms:.2}"),
            sub.to_string(),
            orth.to_string(),
        ]);
    }
    // Analytic-only rows (methods the paper tabulates but nobody runs here).
    for (name, floats) in sumo::optim::memory::analytic_extra(m, n) {
        t.row(&[
            name.to_string(),
            "O(m^3 + n^3)".to_string(),
            format!("{floats}"),
            "-".to_string(),
            "-".to_string(),
            "no".to_string(),
            "no".to_string(),
        ]);
    }
    t.finish().unwrap();

    // The paper's headline: SUMO ≈ 20% less optimizer memory than GaLore.
    let sumo_f = state_memory_floats(OptimKind::Sumo, m, n, r) as f64;
    let galore_f = state_memory_floats(OptimKind::GaLore, m, n, r) as f64;
    println!(
        "SUMO saves {:.1}% of GaLore's optimizer state at ({m}x{n}, r={r})",
        100.0 * (1.0 - sumo_f / galore_f)
    );
}
