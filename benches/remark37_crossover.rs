//! Remark 3.7 / §3.1 — the FLOP trade between exact SVD in the subspace and
//! Newton-Schulz5: analytic FLOP model next to measured wallclock over a
//! (rank, width) sweep. The paper's worked example: at m=8, n=1024 the SVD
//! route costs ≈2× NS5's FLOPs on the *subspace* matrix — but replaces
//! Muon's *full-space* NS5, which is orders of magnitude more work.

use sumo::bench::TableWriter;
use sumo::linalg::{newton_schulz5, orth_svd, Mat};
use sumo::util::timer::time_fn;
use sumo::util::Rng;

/// §3.1 FLOP models (m = rank of the subspace matrix, n = layer width).
/// `svd_flops` models the crate's actual exact-orth implementation — f64
/// one-sided (Hestenes) Jacobi: ~SWEEPS cyclic sweeps over k(k−1)/2 row
/// pairs, each costing ≈12·l flops (three fused dot products plus a
/// two-row rotation), plus the final Wᵀ·Â back-multiply (2k²l).
fn svd_flops(m: u64, n: u64) -> u64 {
    const SWEEPS: u64 = 8;
    let k = m.min(n);
    let l = m.max(n);
    SWEEPS * (k * k.saturating_sub(1) / 2) * 12 * l + 2 * k * k * l
}

fn ns5_flops(m: u64, n: u64) -> u64 {
    n * m * m + m * m * n + 20 * m * m * m + 10 * m * m
}

fn main() {
    let mut rng = Rng::new(37);
    let mut t = TableWriter::new(
        "remark37_crossover",
        &[
            "r (rows)",
            "n (cols)",
            "SVD FLOPs (analytic)",
            "NS5 FLOPs (analytic)",
            "SVD/NS5 (analytic)",
            "orth_svd ms",
            "ns5 ms",
            "SVD/NS5 (measured)",
        ],
    );
    for &(r, n) in &[
        (4usize, 256usize),
        (8, 1024), // the paper's worked example
        (16, 1024),
        (32, 2048),
        (64, 2048),
    ] {
        let m = Mat::randn(r, n, 1.0, &mut rng);
        let s_svd = time_fn(1, 5, || {
            let _ = orth_svd(&m);
        });
        let s_ns5 = time_fn(1, 5, || {
            let _ = newton_schulz5(&m, 5);
        });
        let f_svd = svd_flops(r as u64, n as u64);
        let f_ns5 = ns5_flops(r as u64, n as u64);
        t.row(&[
            format!("{r}"),
            format!("{n}"),
            format!("{:.2e}", f_svd as f64),
            format!("{:.2e}", f_ns5 as f64),
            format!("{:.2}", f_svd as f64 / f_ns5 as f64),
            format!("{:.3}", s_svd.mean() * 1e3),
            format!("{:.3}", s_ns5.mean() * 1e3),
            format!("{:.2}", s_svd.mean() / s_ns5.mean()),
        ]);
    }
    t.finish().unwrap();

    // The macro comparison the remark actually argues: SUMO's subspace SVD
    // vs Muon's full-space NS5 on a real layer shape.
    let (big_m, big_n, r) = (512usize, 512usize, 16usize);
    let full = Mat::randn(big_m, big_n, 1.0, &mut rng);
    let sub = Mat::randn(r, big_n, 1.0, &mut rng);
    let t_full = time_fn(0, 2, || {
        let _ = newton_schulz5(&full, 5);
    });
    let t_sub = time_fn(1, 5, || {
        let _ = orth_svd(&sub);
    });
    println!(
        "full-space NS5 on {big_m}x{big_n}: {:.1} ms vs subspace exact SVD on {r}x{big_n}: {:.2} ms ({:.0}x cheaper)",
        t_full.mean() * 1e3,
        t_sub.mean() * 1e3,
        t_full.mean() / t_sub.mean()
    );
}
