//! Lemma 3.2 — Newton-Schulz orthogonalization error vs condition number:
//! ‖E_i‖_F ≤ √r · (1 − 1/κ)^{2^i}.
//!
//! Sweeps κ and iteration count i on matrices with controlled spectra,
//! measuring the *actual* error of (cubic) Newton-Schulz against the exact
//! SVD polar factor next to the lemma's bound, plus the NS5 (tuned quintic)
//! error the paper's Remark 3.7 prices. The bound must hold for the cubic
//! iteration it is stated for, and the qualitative shape — error grows with
//! κ, shrinks with i — must hold for both.

use sumo::bench::TableWriter;
use sumo::linalg::newton_schulz::newton_schulz_cubic;
use sumo::linalg::{newton_schulz5, orth_svd, Mat};
use sumo::testing::gen::conditioned_mat;
use sumo::util::Rng;

fn fro_err(a: &Mat, b: &Mat) -> f32 {
    let mut d = a.clone();
    d.axpy(-1.0, b);
    d.fro()
}

fn main() {
    let (r, n) = (8usize, 64usize);
    let mut rng = Rng::new(32);
    let mut t = TableWriter::new(
        "lemma32_ns_error",
        &[
            "kappa",
            "iters",
            "bound sqrt(r)(1-1/k)^(2^i)",
            "cubic-NS err",
            "NS5 err",
            "bound holds (cubic)",
        ],
    );
    let mut violations = 0;
    for &kappa in &[2.0f32, 10.0, 100.0, 1000.0] {
        let m = conditioned_mat(&mut rng, r, n, kappa.sqrt()); // κ of A Aᵀ = κ
        let exact = orth_svd(&m);
        for &iters in &[1usize, 3, 5, 8, 12] {
            let bound = (r as f32).sqrt() * (1.0 - 1.0 / kappa).powf(2f32.powi(iters as i32));
            let cubic = fro_err(&newton_schulz_cubic(&m, iters), &exact);
            let ns5 = fro_err(&newton_schulz5(&m, iters), &exact);
            // The lemma bounds the convergent regime; float noise floor 1e-3.
            let holds = cubic <= bound + 1e-2 * (r as f32).sqrt();
            if !holds {
                violations += 1;
            }
            t.row(&[
                format!("{kappa}"),
                format!("{iters}"),
                format!("{bound:.4}"),
                format!("{cubic:.4}"),
                format!("{ns5:.4}"),
                format!("{holds}"),
            ]);
        }
    }
    t.finish().unwrap();
    println!(
        "paper check: error grows with κ at fixed i, shrinks with i at fixed κ; {violations} bound violations"
    );
    // Remark 3.7's worked example: (1−ε)=0.99 at i=5 → error ≈ 0.99^32 ≈ 0.725
    // of the normalized moment — i.e. NS5 is far from converged at κ=100.
    let m = conditioned_mat(&mut rng, r, n, 10.0); // κ(A Aᵀ)=100
    let exact = orth_svd(&m);
    let e5 = fro_err(&newton_schulz_cubic(&m, 5), &exact) / (r as f32).sqrt();
    println!("κ=100, cubic NS5 relative error = {e5:.3} (Remark 3.7 predicts ≈ 0.725·(1±ε))");
}
