"""Layer-2 model checks: shapes, masking, learning signal, preset parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def nano():
    return M.resolve("nano", "lm")


def make_params(cfg, seed=0):
    rng = np.random.default_rng(seed)
    flat = []
    for name, m, n in M.param_specs(cfg):
        if name.endswith("norm"):
            flat.append(jnp.ones((m, n), jnp.float32))
        else:
            std = 0.02 if name == "embed" else (2.0 / (m + n)) ** 0.5
            flat.append(jnp.asarray(rng.normal(0, std, size=(m, n)), jnp.float32))
    return flat


def test_param_specs_counts(nano):
    specs = M.param_specs(nano)
    assert specs[0][0] == "embed"
    assert specs[-1][0] == "final_norm"
    assert len(specs) == 2 + 9 * nano["n_layers"]
    n_params = sum(m * n for _, m, n in specs)
    assert 100_000 < n_params < 500_000  # "nano" ballpark


def test_d_ff_matches_rust_arithmetic():
    # (8*d/3 + 15)//16*16 — must agree with rust/src/config/model_cfg.rs.
    assert M.d_ff_for(64) == 176
    assert M.d_ff_for(128) == 352
    assert M.d_ff_for(192) == 512
    assert M.d_ff_for(256) == 688


def test_train_step_shapes(nano):
    flat = make_params(nano)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(3, nano["vocab"], size=(4, nano["seq_len"])), jnp.int32)
    tgts = jnp.asarray(rng.integers(3, nano["vocab"], size=(4, nano["seq_len"])), jnp.int32)
    out = M.make_train_step(nano)(*flat, toks, tgts)
    loss, grads = out[0], out[1:]
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    assert len(grads) == len(flat)
    for g, p in zip(grads, flat):
        assert g.shape == p.shape
        assert bool(jnp.all(jnp.isfinite(g)))


def test_initial_loss_near_uniform(nano):
    """Fresh model ≈ uniform predictor: CE ≈ log(vocab)."""
    flat = make_params(nano)
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(3, nano["vocab"], size=(4, nano["seq_len"])), jnp.int32)
    loss = M.make_train_step(nano)(*flat, toks, toks)[0]
    assert abs(float(loss) - np.log(nano["vocab"])) < 1.0


def test_sgd_reduces_loss(nano):
    """A few SGD steps on one fixed batch must reduce the loss — the
    learning-signal sanity check for the whole fwd/bwd graph."""
    flat = make_params(nano)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(3, nano["vocab"], size=(4, nano["seq_len"])), jnp.int32)
    tgts = jnp.asarray(rng.integers(3, nano["vocab"], size=(4, nano["seq_len"])), jnp.int32)
    step = jax.jit(M.make_train_step(nano))
    first = None
    for _ in range(8):
        out = step(*flat, toks, tgts)
        loss, grads = out[0], out[1:]
        if first is None:
            first = float(loss)
        flat = [p - 0.5 * g for p, g in zip(flat, grads)]
    assert float(loss) < first - 0.2, (first, float(loss))


def test_pad_targets_are_masked(nano):
    flat = make_params(nano)
    rng = np.random.default_rng(4)
    toks = jnp.asarray(rng.integers(3, nano["vocab"], size=(2, nano["seq_len"])), jnp.int32)
    tgts_a = jnp.asarray(rng.integers(3, nano["vocab"], size=(2, nano["seq_len"])), jnp.int32)
    # Replace second half of targets with PAD: loss must only change through
    # masking, and differ from the full-target loss.
    tgts_b = tgts_a.at[:, nano["seq_len"] // 2 :].set(M.PAD)
    step = M.make_train_step(nano)
    la = float(step(*flat, toks, tgts_a)[0])
    lb = float(step(*flat, toks, tgts_b)[0])
    assert la != lb
    assert np.isfinite(lb)


def test_cls_head_shapes():
    cfg = M.resolve("nano", "cls3")
    flat = make_params(cfg)
    assert M.param_specs(cfg)[-1][0] == "head"
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(3, cfg["vocab"], size=(4, cfg["seq_len"])), jnp.int32)
    labels = jnp.asarray([0, 1, 2, 1], jnp.int32)
    out = M.make_train_step(cfg)(*flat, toks, labels)
    assert out[0].shape == ()
    loss, logits = M.make_eval_step(cfg)(*flat, toks, labels)
    assert logits.shape == (4, 3)
    # Random init: loss finite and within an order of log(n_classes).
    assert 0.0 < float(loss) < 10.0 * np.log(3)


def test_reg_head():
    cfg = M.resolve("nano", "reg")
    flat = make_params(cfg)
    rng = np.random.default_rng(6)
    toks = jnp.asarray(rng.integers(3, cfg["vocab"], size=(4, cfg["seq_len"])), jnp.int32)
    scores = jnp.asarray([0.1, 0.5, 0.9, 0.3], jnp.float32)
    out = M.make_train_step(cfg)(*flat, toks, scores)
    assert np.isfinite(float(out[0]))
    _, logits = M.make_eval_step(cfg)(*flat, toks, scores)
    assert logits.shape == (4, 1)


def test_logits_step_shape(nano):
    flat = make_params(nano)
    rng = np.random.default_rng(7)
    toks = jnp.asarray(rng.integers(3, nano["vocab"], size=(2, nano["seq_len"])), jnp.int32)
    (logits,) = M.make_logits_step(nano)(*flat, toks)
    assert logits.shape == (2, nano["vocab"])


def test_causality():
    """Changing a future token must not affect earlier LM logits."""
    cfg = M.resolve("nano", "lm")
    flat = make_params(cfg)
    rng = np.random.default_rng(8)
    toks = jnp.asarray(rng.integers(3, cfg["vocab"], size=(1, cfg["seq_len"])), jnp.int32)
    params = dict(zip([n for n, _, _ in M.param_specs(cfg)], flat))
    h1 = M.backbone(params, cfg, toks)
    toks2 = toks.at[0, -1].set((int(toks[0, -1]) + 5) % cfg["vocab"])
    h2 = M.backbone(params, cfg, toks2)
    np.testing.assert_allclose(
        np.asarray(h1[0, : cfg["seq_len"] - 1]),
        np.asarray(h2[0, : cfg["seq_len"] - 1]),
        atol=1e-5,
    )
    assert not np.allclose(np.asarray(h1[0, -1]), np.asarray(h2[0, -1]))
