"""AOT pipeline: lowering produces parseable HLO text + coherent manifest."""

import json
import os

import pytest

from compile import aot
from compile import model as M
from compile import optim as O


def test_to_hlo_text_smoke(tmp_path):
    import jax
    import jax.numpy as jnp

    def fn(x, y):
        return (x @ y + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    lowered = jax.jit(fn).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text


def test_nano_model_artifacts(tmp_path):
    out = str(tmp_path)
    manifest = aot.build_all(out, only="nano_lm")
    entry = manifest["models"]["nano_lm"]
    for key in ["train", "eval", "logits"]:
        path = os.path.join(out, entry[key])
        assert os.path.exists(path), key
        text = open(path).read()
        assert "ENTRY" in text
    # Param specs mirror model.param_specs.
    cfg = M.resolve("nano", "lm")
    assert entry["params"] == [[n, m, k] for n, m, k in M.param_specs(cfg)]
    # Manifest is valid JSON on disk.
    with open(os.path.join(out, "manifest.json")) as f:
        j = json.load(f)
    assert j["batch"] == aot.BATCH


def test_projected_shapes_unique_and_2d():
    cfg = M.resolve("small", "lm")
    shapes = aot.projected_shapes(cfg)
    assert len(shapes) == len(set(shapes))
    assert all(m > 1 and n > 1 for m, n in shapes)
    assert (cfg["vocab"], cfg["d_model"]) in shapes


def test_sumo_update_arg_specs_match_projection_side():
    # m >= n: left projection, moment is (r, n).
    args = O.sumo_update_args(64, 32, 4)
    assert args[1].shape == (4, 32)
    assert args[2].shape == (64, 4)
    # m < n: right projection, moment is (m, r).
    args = O.sumo_update_args(32, 64, 4)
    assert args[1].shape == (32, 4)
    assert args[2].shape == (64, 4)
