"""Layer-1 kernel correctness: Pallas vs pure oracles (ref.py).

Hypothesis sweeps shapes/ranks/seeds; every kernel must match its oracle to
float32 tolerance. These tests are the core correctness signal for the HLO
that the Rust runtime executes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import jacobi_eigh, matmul, matmul_tiled, newton_schulz5, orth_svd
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def rand(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.sampled_from([1, 3, 16, 64, 96]),
    k=st.sampled_from([1, 8, 48, 128]),
    n=st.sampled_from([1, 4, 32, 88]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, m, k), rand(rng, k, n)
    got = np.asarray(matmul_tiled(a, b))
    want = np.asarray(ref.matmul_ref(a, b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_grad_matches_jnp():
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    a, b = rand(rng, 32, 16), rand(rng, 16, 24)

    g_kernel = jax.grad(lambda x: jnp.sum(matmul(x, b) ** 2))(a)
    g_ref = jax.grad(lambda x: jnp.sum((x @ b) ** 2))(a)
    np.testing.assert_allclose(np.asarray(g_kernel), np.asarray(g_ref), rtol=1e-4, atol=1e-4)

    gb_kernel = jax.grad(lambda x: jnp.sum(matmul(a, x) ** 2))(b)
    gb_ref = jax.grad(lambda x: jnp.sum((a @ x) ** 2))(b)
    np.testing.assert_allclose(np.asarray(gb_kernel), np.asarray(gb_ref), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# orth_svd (SUMO Block 2)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    r=st.sampled_from([2, 4, 8, 16]),
    n=st.sampled_from([64, 200]),
    seed=st.integers(0, 2**31 - 1),
)
def test_orth_svd_matches_lapack(r, n, seed):
    # n >= 2r keeps sigma_min of a Gaussian matrix bounded away from zero
    # (Marchenko-Pastur), where the polar factor is well-conditioned and a
    # float32-vs-float64 element-wise comparison is meaningful. Square /
    # near-square inputs are covered by the orthogonality property below
    # (the polar factor itself is unstable as sigma_min -> 0).
    rng = np.random.default_rng(seed)
    m = rand(rng, r, n)
    got = np.asarray(orth_svd(m))
    want = ref.orth_svd_ref(m)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=2e-4)


@settings(**SETTINGS)
@given(r=st.sampled_from([2, 4, 8, 24]), n=st.sampled_from([32, 100]), seed=st.integers(0, 2**31 - 1))
def test_orth_svd_output_is_orthogonal(r, n, seed):
    rng = np.random.default_rng(seed)
    o = np.asarray(orth_svd(rand(rng, r, n)))
    np.testing.assert_allclose(o @ o.T, np.eye(r), atol=5e-4)


def test_orth_svd_tall_input():
    rng = np.random.default_rng(1)
    o = np.asarray(orth_svd(rand(rng, 64, 8)))
    np.testing.assert_allclose(o.T @ o, np.eye(8), atol=5e-4)


def test_orth_svd_rank_deficient():
    rng = np.random.default_rng(2)
    a = rand(rng, 2, 32)
    m = np.vstack([a, 0.5 * a])  # rank 2 in a 4x32
    o = np.asarray(orth_svd(m))
    assert np.all(np.isfinite(o))
    s = np.linalg.svd(o, compute_uv=False)
    # Singular values must be ~0 or ~1 (pseudo-inverse convention).
    assert np.all((s < 0.05) | (s > 0.95)), s


def test_orth_svd_rank1_row():
    m = np.ones((1, 16), np.float32) * 3.0
    o = np.asarray(orth_svd(m))
    np.testing.assert_allclose(np.linalg.norm(o), 1.0, rtol=1e-5)


def test_orth_is_fixed_point_on_orthogonal():
    rng = np.random.default_rng(3)
    q, _ = np.linalg.qr(rand(rng, 32, 6))
    o = np.asarray(orth_svd(q.T.astype(np.float32)))
    np.testing.assert_allclose(o, q.T, atol=1e-3)


# ---------------------------------------------------------------------------
# jacobi_eigh
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(r=st.sampled_from([2, 3, 8, 16]), seed=st.integers(0, 2**31 - 1))
def test_jacobi_eigh_matches_lapack(r, seed):
    rng = np.random.default_rng(seed)
    b = rand(rng, r, 2 * r)
    gram = (b @ b.T).astype(np.float32)
    w, v = jacobi_eigh(gram)
    w, v = np.asarray(w), np.asarray(v)
    w_ref, _ = ref.eigh_ref(gram)
    np.testing.assert_allclose(w, w_ref, rtol=1e-3, atol=1e-3)
    # V diag(w) V^T reconstructs.
    np.testing.assert_allclose(v @ np.diag(w) @ v.T, gram, rtol=1e-2, atol=1e-2)


# ---------------------------------------------------------------------------
# newton_schulz5
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(r=st.sampled_from([4, 8]), n=st.sampled_from([32, 96]), seed=st.integers(0, 2**31 - 1))
def test_ns5_matches_ref(r, n, seed):
    rng = np.random.default_rng(seed)
    m = rand(rng, r, n)
    got = np.asarray(newton_schulz5(m))
    want = ref.newton_schulz5_ref(m)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_ns5_error_grows_with_condition_number_lemma32():
    """Lemma 3.2's qualitative claim: NS error increases with kappa."""
    rng = np.random.default_rng(7)

    def err(kappa):
        r, n = 8, 64
        q, _ = np.linalg.qr(rng.normal(size=(n, r)))
        s = np.linspace(1.0, 1.0 / kappa, r)
        m = (np.diag(s) @ q.T).astype(np.float32)
        exact = ref.orth_svd_ref(m)
        approx = np.asarray(newton_schulz5(m))
        return np.abs(approx - exact).max()

    assert err(1000.0) > err(2.0)


def test_ns5_iterations_reduce_error_for_moderate_kappa():
    rng = np.random.default_rng(11)
    m = rand(rng, 8, 64)
    exact = ref.orth_svd_ref(m)
    e1 = np.abs(np.asarray(newton_schulz5(m, iters=1)) - exact).max()
    e5 = np.abs(np.asarray(newton_schulz5(m, iters=5)) - exact).max()
    assert e5 < e1
