"""Optimizer update graphs vs numpy oracles.

These same semantics are implemented natively in rust/src/optim/; the Rust
integration tests then check HLO-vs-native equivalence through the PJRT
runtime, closing the loop: numpy oracle == JAX graph == native Rust.
"""

import numpy as np
import pytest

from compile import optim as O
from compile.kernels import ref


def rand(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


HP = dict(lr=0.01, beta=0.9, wd=0.05, gamma=1.1, alpha=1.0)


def sumo_update_oracle(w, mom, q, g, o_prev, left, use_ns5=False, **hp):
    ghat = (q.T @ g) if left else (g @ q)
    mom_new = hp["beta"] * mom + (1 - hp["beta"]) * ghat
    o = ref.newton_schulz5_ref(mom_new) if use_ns5 else ref.orth_svd_ref(mom_new)
    o_norm = np.linalg.norm(o)
    if o_prev > 0 and o_norm / max(o_prev, 1e-12) > hp["gamma"]:
        o = o * (hp["gamma"] * o_prev / max(o_norm, 1e-30))
    full = (q @ o) if left else (o @ q.T)
    scale = 0.2 * max(w.shape) ** 0.5
    w_new = w - hp["lr"] * hp["alpha"] * scale * full - hp["lr"] * hp["wd"] * w
    return w_new, mom_new, o_norm


@pytest.mark.parametrize("m,n,r", [(64, 32, 4), (32, 64, 4), (64, 64, 8)])
def test_sumo_update_matches_oracle(m, n, r):
    rng = np.random.default_rng(0)
    left = O.project_left(m, n)
    w, g = rand(rng, m, n), rand(rng, m, n)
    mom = rand(rng, r, n) if left else rand(rng, m, r)
    qbase = rand(rng, m if left else n, r)
    q, _ = np.linalg.qr(qbase)
    q = q.astype(np.float32)
    o_prev = np.float32(2.0)
    step = O.make_sumo_update(m, n, r)
    got = step(w, mom, q, g, o_prev, *(np.float32(HP[k]) for k in ["lr", "beta", "wd", "gamma", "alpha"]))
    want = sumo_update_oracle(w, mom, q, g, float(o_prev), left, **HP)
    for got_x, want_x, tol in zip(got, want, [5e-4, 1e-4, 1e-3]):
        np.testing.assert_allclose(np.asarray(got_x), want_x, rtol=1e-2, atol=tol)


def test_sumo_update_limiter_engages():
    """With a tiny o_prev_norm, the limiter must cap the step size."""
    rng = np.random.default_rng(1)
    m, n, r = 64, 32, 4
    w, g = rand(rng, m, n), rand(rng, m, n)
    mom = rand(rng, r, n)
    q, _ = np.linalg.qr(rand(rng, m, r))
    q = q.astype(np.float32)
    step = O.make_sumo_update(m, n, r)
    hp = [np.float32(HP[k]) for k in ["lr", "beta", "wd", "gamma", "alpha"]]
    w_small_prev = np.asarray(step(w, mom, q, g, np.float32(0.01), *hp)[0])
    w_big_prev = np.asarray(step(w, mom, q, g, np.float32(100.0), *hp)[0])
    # Limited step moves weights strictly less.
    d_small = np.abs(w_small_prev - w).sum()
    d_big = np.abs(w_big_prev - w).sum()
    assert d_small < d_big


def test_sumo_update_ns5_variant_differs():
    rng = np.random.default_rng(2)
    m, n, r = 64, 32, 4
    w, g = rand(rng, m, n), rand(rng, m, n)
    # Ill-conditioned moment: NS5 differs visibly from exact SVD.
    mom = np.diag([1.0, 0.1, 0.01, 0.001]).astype(np.float32) @ rand(rng, r, n)
    q, _ = np.linalg.qr(rand(rng, m, r))
    q = q.astype(np.float32)
    hp = [np.float32(HP[k]) for k in ["lr", "beta", "wd", "gamma", "alpha"]]
    w_svd = np.asarray(O.make_sumo_update(m, n, r)(w, mom, q, g, np.float32(0.0), *hp)[0])
    w_ns5 = np.asarray(
        O.make_sumo_update(m, n, r, use_ns5=True)(w, mom, q, g, np.float32(0.0), *hp)[0]
    )
    assert np.abs(w_svd - w_ns5).max() > 1e-5


def test_sumo_refresh_orthonormal_and_transport():
    rng = np.random.default_rng(3)
    m, n, r = 96, 48, 6
    # Low-rank-ish gradient.
    g = (rand(rng, m, r) @ rand(rng, r, n)).astype(np.float32)
    q_prev, _ = np.linalg.qr(rand(rng, m, r))
    q_prev = q_prev.astype(np.float32)
    mom = rand(rng, r, n)
    sketch = min(r + 4, n)
    omega = rand(rng, n, sketch)
    q_new, m_t = O.make_sumo_refresh(m, n, r)(g, q_prev, mom, omega)
    q_new, m_t = np.asarray(q_new), np.asarray(m_t)
    np.testing.assert_allclose(q_new.T @ q_new, np.eye(r), atol=2e-3)
    # Q captures the column space of the rank-r G.
    res = g - q_new @ (q_new.T @ g)
    assert np.linalg.norm(res) / np.linalg.norm(g) < 1e-2
    # Transport: M' = (Q_new^T Q_prev) M.
    want = (q_new.T @ q_prev) @ mom
    np.testing.assert_allclose(m_t, want, rtol=1e-2, atol=1e-3)


def test_adam_update_matches_oracle():
    rng = np.random.default_rng(4)
    m, n = 32, 16
    w, g = rand(rng, m, n), rand(rng, m, n)
    mm, vv = np.zeros((m, n), np.float32), np.zeros((m, n), np.float32)
    step = O.make_adam_update(m, n)
    lr, b1, b2, eps, wd, t = 0.01, 0.9, 0.999, 1e-8, 0.0, 1.0
    got = step(w, mm, vv, g, *(np.float32(x) for x in [lr, b1, b2, eps, wd, t]))
    m_new = (1 - b1) * g
    v_new = (1 - b2) * g * g
    mhat = m_new / (1 - b1**t)
    vhat = v_new / (1 - b2**t)
    w_new = w - lr * mhat / (np.sqrt(vhat) + eps)
    np.testing.assert_allclose(np.asarray(got[0]), w_new, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got[1]), m_new, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got[2]), v_new, rtol=1e-5, atol=1e-6)


def test_galore_update_is_subspace_adam():
    rng = np.random.default_rng(5)
    m, n, r = 48, 24, 4
    w, g = rand(rng, m, n), rand(rng, m, n)
    q, _ = np.linalg.qr(rand(rng, m, r))
    q = q.astype(np.float32)
    mm = np.zeros((r, n), np.float32)
    vv = np.zeros((r, n), np.float32)
    lr, b1, b2, eps, wd, alpha, t = 0.01, 0.9, 0.999, 1e-8, 0.0, 1.0, 1.0
    got = O.make_galore_update(m, n, r)(
        w, mm, vv, q, g, *(np.float32(x) for x in [lr, b1, b2, eps, wd, alpha, t])
    )
    ghat = q.T @ g
    m_new = (1 - b1) * ghat
    v_new = (1 - b2) * ghat * ghat
    upd = (m_new / (1 - b1**t)) / (np.sqrt(v_new / (1 - b2**t)) + eps)
    w_new = w - lr * alpha * (q @ upd)
    np.testing.assert_allclose(np.asarray(got[0]), w_new, rtol=1e-3, atol=1e-4)


def test_muon_update_uses_ns5():
    rng = np.random.default_rng(6)
    m, n = 32, 64
    w, g = rand(rng, m, n), rand(rng, m, n)
    mom = np.zeros((m, n), np.float32)
    lr, beta, wd = 0.01, 0.9, 0.0
    got = O.make_muon_update(m, n)(w, mom, g, *(np.float32(x) for x in [lr, beta, wd]))
    mom_new = (1 - beta) * g
    o = ref.newton_schulz5_ref(mom_new)
    w_new = w - lr * (0.2 * max(m, n) ** 0.5) * o
    np.testing.assert_allclose(np.asarray(got[0]), w_new, rtol=1e-3, atol=1e-4)


def test_rms_scale_formula():
    assert O.rms_scale(2048, 256) == pytest.approx(0.2 * 2048**0.5)
    assert O.rms_scale(64, 688) == pytest.approx(0.2 * 688**0.5)
