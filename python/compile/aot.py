"""AOT lowering: JAX/Pallas programs -> HLO *text* artifacts + manifest.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1 rejects;
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Run via ``make artifacts`` (no-op when sources are unchanged):

    cd python && python -m compile.aot --out ../artifacts

Outputs:
    artifacts/<id>.hlo.txt      one per lowered program
    artifacts/manifest.json     shapes/dtypes/param specs for the Rust side
"""

import argparse
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import model as M
from . import optim as O

BATCH = 8  # baked into every model artifact; mirrored in the manifest


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, args, out_dir: str, name: str, quiet=False) -> str:
    t0 = time.time()
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    if not quiet:
        print(f"  {name}: {len(text) / 1e6:.2f} MB in {time.time() - t0:.1f}s",
              flush=True)
    return f"{name}.hlo.txt"


# ---------------------------------------------------------------------------
# manifest plan
# ---------------------------------------------------------------------------

# Models to lower: (preset, head). nano drives tests, micro/mini the
# fine-tuning benches, small the e2e pretraining driver.
MODEL_PLAN = [
    ("nano", "lm"),
    ("nano", "cls2"),
    ("micro", "lm"),
    ("micro", "cls2"),
    ("micro", "cls3"),
    ("micro", "reg"),
    ("mini", "lm"),
    ("small", "lm"),
]

# SUMO update/refresh artifacts per model preset: rank used by the e2e
# driver + integration tests (native Rust optimizers cover other ranks).
SUMO_RANK = {"nano": 4, "micro": 8, "mini": 8, "small": 16}

# Cross-validation updates for baselines (nano shapes only; native Rust
# implementations are the bench path).
BASELINE_SHAPES = [(64, 64)]


def projected_shapes(cfg) -> list:
    """Unique 2-D layer shapes that low-rank optimizers project."""
    shapes = []
    for name, m, n in M.param_specs(cfg):
        if m > 1 and n > 1 and not name.endswith("norm") and name != "head":
            if (m, n) not in shapes:
                shapes.append((m, n))
    return shapes


def build_all(out_dir: str, only: str | None = None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"batch": BATCH, "models": {}, "optim": {}, "kernels": {}}

    for preset, head in MODEL_PLAN:
        cfg = M.resolve(preset, head)
        mid = f"{preset}_{head}"
        if only and only not in mid:
            continue
        print(f"model {mid}", flush=True)
        flat, tokens, labels = M.example_args(cfg, BATCH)
        train_file = lower_to_file(
            M.make_train_step(cfg), (*flat, tokens, labels), out_dir, f"{mid}_train"
        )
        eval_file = lower_to_file(
            M.make_eval_step(cfg), (*flat, tokens, labels), out_dir, f"{mid}_eval"
        )
        entry = {
            "cfg": cfg,
            "params": [[name, m, n] for name, m, n in M.param_specs(cfg)],
            "train": train_file,
            "eval": eval_file,
            "batch": BATCH,
            "label_dtype": "f32" if head == "reg" else "i32",
        }
        if head == "lm":
            entry["logits"] = lower_to_file(
                M.make_logits_step(cfg), (*flat, tokens), out_dir, f"{mid}_logits"
            )
        manifest["models"][mid] = entry

    # SUMO per-layer update + refresh artifacts.
    for preset in ["nano", "small"]:
        if only and "sumo" not in (only or "") and only not in preset:
            if only:
                continue
        cfg = M.resolve(preset, "lm")
        r = SUMO_RANK[preset]
        for m, n in projected_shapes(cfg):
            sid = f"sumo_update_{m}x{n}_r{r}"
            if sid not in manifest["optim"]:
                print(f"optim {sid}", flush=True)
                manifest["optim"][sid] = {
                    "kind": "sumo_update",
                    "m": m,
                    "n": n,
                    "rank": r,
                    "left": O.project_left(m, n),
                    "file": lower_to_file(
                        O.make_sumo_update(m, n, r),
                        O.sumo_update_args(m, n, r),
                        out_dir,
                        sid,
                    ),
                }
            rid = f"sumo_refresh_{m}x{n}_r{r}"
            if rid not in manifest["optim"]:
                print(f"optim {rid}", flush=True)
                manifest["optim"][rid] = {
                    "kind": "sumo_refresh",
                    "m": m,
                    "n": n,
                    "rank": r,
                    "left": O.project_left(m, n),
                    "oversample": 4,
                    "file": lower_to_file(
                        O.make_sumo_refresh(m, n, r),
                        O.sumo_refresh_args(m, n, r),
                        out_dir,
                        rid,
                    ),
                }

    # Baseline update graphs (cross-validated against native Rust impls).
    if not only:
        import jax.numpy as jnp

        s = jax.ShapeDtypeStruct
        for m, n in BASELINE_SHAPES:
            w = s((m, n), jnp.float32)
            print(f"optim baselines {m}x{n}", flush=True)
            manifest["optim"][f"muon_update_{m}x{n}"] = {
                "kind": "muon_update",
                "m": m,
                "n": n,
                "file": lower_to_file(
                    O.make_muon_update(m, n),
                    [w, w, w, *O.scalar_args(3)],
                    out_dir,
                    f"muon_update_{m}x{n}",
                ),
            }
            manifest["optim"][f"adam_update_{m}x{n}"] = {
                "kind": "adam_update",
                "m": m,
                "n": n,
                "file": lower_to_file(
                    O.make_adam_update(m, n),
                    [w, w, w, w, *O.scalar_args(6)],
                    out_dir,
                    f"adam_update_{m}x{n}",
                ),
            }
            r = 4
            left = O.project_left(m, n)
            q = s((m if left else n, r), jnp.float32)
            mom = s((r, n) if left else (m, r), jnp.float32)
            manifest["optim"][f"galore_update_{m}x{n}_r{r}"] = {
                "kind": "galore_update",
                "m": m,
                "n": n,
                "rank": r,
                "left": left,
                "file": lower_to_file(
                    O.make_galore_update(m, n, r),
                    [w, mom, mom, q, w, *O.scalar_args(7)],
                    out_dir,
                    f"galore_update_{m}x{n}_r{r}",
                ),
            }

        # Standalone kernel artifacts (runtime smoke tests / kernel benches).
        from .kernels import newton_schulz5, orth_svd

        km = s((8, 64), jnp.float32)
        manifest["kernels"]["orth_svd_8x64"] = {
            "file": lower_to_file(
                lambda x: (orth_svd(x),), [km], out_dir, "orth_svd_8x64"
            ),
            "m": 8,
            "n": 64,
        }
        manifest["kernels"]["ns5_8x64"] = {
            "file": lower_to_file(
                lambda x: (newton_schulz5(x),), [km], out_dir, "ns5_8x64"
            ),
            "m": 8,
            "n": 64,
        }

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"manifest: {len(manifest['models'])} models, "
          f"{len(manifest['optim'])} optim graphs, "
          f"{len(manifest['kernels'])} kernels", flush=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter for ids")
    args = ap.parse_args()
    build_all(args.out, args.only)


if __name__ == "__main__":
    main()
