"""Layer-2 optimizer update graphs, built on the Layer-1 kernels.

Each builder returns a jax function that ``aot.py`` lowers to one HLO
artifact per (layer shape, rank). The Rust coordinator executes these on
the request path — one call per layer per step — so Python never runs at
training time.

Conventions (mirrors rust/src/optim/):
  * Projection side follows the paper: for W (m x n) with m >= n the
    subspace basis Q is m x r and the projected gradient is Q^T G (r x n);
    for m < n, Q is n x r and the projected gradient is G Q (m x r).
  * The moment update is the convex-combination form of Appendix C:
    M <- beta * M + (1 - beta) * Ghat.
  * Block 3 (norm-growth limiter) and Block 4 (back-projection + weight
    decay + RMS-consistent scaling) are fused into the same artifact.
"""

import jax
import jax.numpy as jnp

from .kernels import matmul_tiled, newton_schulz5, orth_svd


def project_left(m: int, n: int) -> bool:
    """True when the basis multiplies from the left (m >= n)."""
    return m >= n


def rms_scale(m: int, n: int) -> float:
    """Muon-style RMS-consistent per-layer LR scale (§Method Block 4 /
    Liu et al. 2025): sqrt(max(m, n)) * 0.2."""
    return 0.2 * float(max(m, n)) ** 0.5


def make_sumo_update(m: int, n: int, r: int, use_ns5: bool = False, ns_iters: int = 5):
    """SUMO Blocks 2-4 for one layer shape.

    Inputs:  W (m,n), M (r,n) or (m,r), Q (m,r) or (n,r), G (m,n),
             o_prev_norm (), lr (), beta (), wd (), gamma (), alpha ()
    Outputs: W', M', o_norm
    """
    left = project_left(m, n)

    def step(w, mom, q, g, o_prev_norm, lr, beta, wd, gamma, alpha):
        # Block 1 tail: project the gradient into the subspace.
        ghat = matmul_tiled(q.T, g) if left else matmul_tiled(g, q)
        # Block 2: moment EMA + exact orthogonalization (or NS5 ablation).
        mom_new = beta * mom + (1.0 - beta) * ghat
        if use_ns5:
            o = newton_schulz5(mom_new, iters=ns_iters)
        else:
            o = orth_svd(mom_new)
        # Block 3: norm-growth limiter (NL), gamma-threshold form.
        o_norm = jnp.sqrt(jnp.sum(o * o))
        prev = jnp.maximum(o_prev_norm, 1e-12)
        ratio = o_norm / prev
        limited = jnp.where(
            (ratio > gamma) & (o_prev_norm > 0.0),
            o * (gamma * prev / jnp.maximum(o_norm, 1e-30)),
            o,
        )
        # Block 4: back-project + weight decay, RMS-consistent scale.
        full = matmul_tiled(q, limited) if left else matmul_tiled(limited, q.T)
        scale = rms_scale(m, n)
        w_new = w - lr * alpha * scale * full - lr * wd * w
        return w_new, mom_new, o_norm

    return step


def sumo_update_args(m: int, n: int, r: int):
    left = project_left(m, n)
    mom_shape = (r, n) if left else (m, r)
    q_shape = (m, r) if left else (n, r)
    f32 = jnp.float32
    s = jax.ShapeDtypeStruct
    return [
        s((m, n), f32),  # W
        s(mom_shape, f32),  # M
        s(q_shape, f32),  # Q
        s((m, n), f32),  # G
        s((), f32),  # o_prev_norm
        s((), f32),  # lr
        s((), f32),  # beta
        s((), f32),  # wd
        s((), f32),  # gamma
        s((), f32),  # alpha
    ]


def make_sumo_refresh(m: int, n: int, r: int, power_iters: int = 1):
    """Block 1 + Block 1.1: randomized range finder on G and moment
    transport into the new subspace.

    Inputs:  G (m,n), Q_prev, M_prev, Omega (sketch test matrix)
    Outputs: Q_new, M_transported
    The Gaussian Omega is drawn by the Rust coordinator (seeded) so the
    graph stays deterministic and RNG-free.
    """
    left = project_left(m, n)

    def mgs_qr_q(y):
        """Orthonormal basis of the columns of y via modified Gram-Schmidt
        (two passes), LAPACK-free so it lowers to plain HLO."""
        cols = y.shape[1]

        def body(i, ym):
            col = ym[:, i]
            # Subtract projections onto all previous columns (mask j >= i).
            idx = jnp.arange(cols)
            mask = (idx < i).astype(y.dtype)
            for _ in range(2):
                coeffs = (ym.T @ col) * mask  # (cols,)
                col = col - ym @ coeffs
            norm = jnp.sqrt(jnp.sum(col * col))
            col = jnp.where(norm > 1e-20, col / norm, col * 0.0)
            return ym.at[:, i].set(col)

        return jax.lax.fori_loop(0, cols, body, y)

    def refresh(g, q_prev, m_prev, omega):
        a = g if left else g.T  # work on the tall side: (big, small)
        y = matmul_tiled(a, omega)  # (big, r+p)
        for _ in range(power_iters):
            q = mgs_qr_q(y)
            z = matmul_tiled(a.T, q)
            qz = mgs_qr_q(z)
            y = matmul_tiled(a, qz)
        q_full = mgs_qr_q(y)
        q_new = q_full[:, :r]
        # Block 1.1: transport the moment between subspaces.
        rmat = matmul_tiled(q_new.T, q_prev)  # (r, r)
        m_t = matmul_tiled(rmat, m_prev) if left else matmul_tiled(m_prev, rmat.T)
        return q_new, m_t

    return refresh


def sumo_refresh_args(m: int, n: int, r: int, oversample: int = 4):
    left = project_left(m, n)
    big, small = (m, n) if left else (n, m)
    sketch = min(r + oversample, small)
    mom_shape = (r, n) if left else (m, r)
    q_shape = (big, r)
    f32 = jnp.float32
    s = jax.ShapeDtypeStruct
    return [
        s((m, n), f32),  # G
        s(q_shape, f32),  # Q_prev
        s(mom_shape, f32),  # M_prev
        s((small, sketch), f32),  # Omega
    ]


def make_muon_update(m: int, n: int, ns_iters: int = 5):
    """Muon baseline: full-space NS5 orthogonalization of the moment."""

    def step(w, mom, g, lr, beta, wd):
        mom_new = beta * mom + (1.0 - beta) * g
        o = newton_schulz5(mom_new, iters=ns_iters)
        scale = rms_scale(m, n)
        w_new = w - lr * scale * o - lr * wd * w
        return w_new, mom_new

    return step


def make_adam_update(m: int, n: int):
    """Adam with bias correction; t passed as a float scalar."""

    def step(w, mm, vv, g, lr, beta1, beta2, eps, wd, t):
        m_new = beta1 * mm + (1.0 - beta1) * g
        v_new = beta2 * vv + (1.0 - beta2) * g * g
        mhat = m_new / (1.0 - beta1**t)
        vhat = v_new / (1.0 - beta2**t)
        w_new = w - lr * mhat / (jnp.sqrt(vhat) + eps) - lr * wd * w
        return w_new, m_new, v_new

    return step


def make_galore_update(m: int, n: int, r: int):
    """GaLore: Adam in the projected subspace, back-projected (scale alpha)."""
    left = project_left(m, n)

    def step(w, mm, vv, q, g, lr, beta1, beta2, eps, wd, alpha, t):
        ghat = matmul_tiled(q.T, g) if left else matmul_tiled(g, q)
        m_new = beta1 * mm + (1.0 - beta1) * ghat
        v_new = beta2 * vv + (1.0 - beta2) * ghat * ghat
        mhat = m_new / (1.0 - beta1**t)
        vhat = v_new / (1.0 - beta2**t)
        upd = mhat / (jnp.sqrt(vhat) + eps)
        full = matmul_tiled(q, upd) if left else matmul_tiled(upd, q.T)
        w_new = w - lr * alpha * full - lr * wd * w
        return w_new, m_new, v_new

    return step


def scalar_args(k: int):
    return [jax.ShapeDtypeStruct((), jnp.float32) for _ in range(k)]
