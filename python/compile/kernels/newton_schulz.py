"""Newton-Schulz5 orthogonalization kernel — Muon's approximation, kept as
the paper's ablation baseline (Table 2 "SUMO (Newton-Schulz5)" rows and the
Lemma 3.2 error-bound experiments).

One Pallas block holds X (r x n) and the r x r Gram; the quintic
X <- aX + (bA + cA^2)X with A = X X^T runs ``iters`` times in VMEM.
Coefficients are Muon's tuned (3.4445, -4.7750, 2.0315).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NS_A, NS_B, NS_C = 3.4445, -4.7750, 2.0315


def _ns5_block(m, iters):
    norm = jnp.maximum(jnp.sqrt(jnp.sum(m * m)), 1e-30)
    x0 = m / norm

    def body(_, x):
        a = jnp.dot(x, x.T, preferred_element_type=jnp.float32)
        a2 = jnp.dot(a, a, preferred_element_type=jnp.float32)
        bmat = NS_B * a + NS_C * a2
        return NS_A * x + jnp.dot(bmat, x, preferred_element_type=jnp.float32)

    return jax.lax.fori_loop(0, iters, body, x0)


def _ns5_kernel(m_ref, o_ref, *, iters):
    o_ref[...] = _ns5_block(m_ref[...], iters)


@functools.partial(jax.jit, static_argnames=("iters", "interpret"))
def newton_schulz5(m, iters: int = 5, interpret: bool = True):
    """Approximate polar factor via ``iters`` quintic Newton-Schulz steps."""
    r, n = m.shape
    if r > n:
        return newton_schulz5(m.T, iters=iters, interpret=interpret).T
    kernel = functools.partial(_ns5_kernel, iters=iters)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((r, n), jnp.float32),
        interpret=interpret,
    )(m.astype(jnp.float32))
