"""Tiled Pallas matmul kernel with a custom VJP.

This is the workhorse of the SUMO update graphs (Q^T G projections, Q O
back-projections) and is also called from the Layer-2 model's MLP so the
kernel lowers into the train-step HLO.

TPU thinking (DESIGN.md §Hardware-Adaptation): the grid tiles HBM->VMEM
transfers at (TM, TK)x(TK, TN) blocks sized for the MXU's 128x128 systolic
array; the k-dimension of the grid accumulates into the output block, which
stays resident in VMEM across the k loop ("revisiting" schedule). On CPU we
run the same program under interpret=True.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Preferred tile edge (MXU native tile). Actual tiles divide the problem.
_PREF_TILE = 128


def _pick_tile(dim: int, pref: int = _PREF_TILE) -> int:
    """Largest divisor of ``dim`` that is <= pref (prefers pref itself)."""
    if dim <= pref:
        return dim
    for t in range(pref, 0, -1):
        if dim % t == 0:
            return t
    return dim


def _mm_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul_tiled(a, b, interpret: bool = True):
    """C = A @ B via the tiled Pallas kernel (no autodiff — see matmul)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"matmul dims {a.shape} x {b.shape}"
    tm, tk, tn = _pick_tile(m), _pick_tile(k), _pick_tile(n)
    grid = (m // tm, n // tn, k // tk)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32))


@jax.custom_vjp
def matmul(a, b):
    """Differentiable A @ B where forward *and* both backward products run
    through the Pallas kernel (so model fwd/bwd HLO contains the kernel)."""
    return matmul_tiled(a, b)


def _matmul_fwd(a, b):
    return matmul_tiled(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    da = matmul_tiled(g, b.T)
    db = matmul_tiled(a.T, g)
    return da, db


matmul.defvjp(_matmul_fwd, _matmul_bwd)
