"""Pure-jnp / numpy oracles for every Layer-1 kernel.

pytest (with hypothesis sweeps) asserts each Pallas kernel against these.
They are intentionally written in the most obvious way possible.
"""

import jax.numpy as jnp
import numpy as np

NS_A, NS_B, NS_C = 3.4445, -4.7750, 2.0315


def matmul_ref(a, b):
    return jnp.asarray(a, jnp.float32) @ jnp.asarray(b, jnp.float32)


def orth_svd_ref(m):
    """Exact polar factor U V^T via numpy's LAPACK SVD (build-time only)."""
    m = np.asarray(m, np.float64)
    transpose = m.shape[0] > m.shape[1]
    if transpose:
        m = m.T
    u, s, vt = np.linalg.svd(m, full_matrices=False)
    # Pseudo-inverse convention for (near-)zero singular values.
    keep = s > 1e-7 * max(s[0], 1e-30)
    o = (u[:, keep] @ vt[keep, :]).astype(np.float32)
    return o.T if transpose else o


def newton_schulz5_ref(m, iters=5):
    m = np.asarray(m, np.float32)
    transpose = m.shape[0] > m.shape[1]
    if transpose:
        m = m.T
    x = m / max(np.linalg.norm(m), 1e-30)
    for _ in range(iters):
        a = x @ x.T
        b = NS_B * a + NS_C * (a @ a)
        x = NS_A * x + b @ x
    return x.T if transpose else x


def eigh_ref(b):
    """Symmetric eigendecomposition, eigenvalues descending."""
    w, v = np.linalg.eigh(np.asarray(b, np.float64))
    order = np.argsort(-w)
    return w[order].astype(np.float32), v[:, order].astype(np.float32)
