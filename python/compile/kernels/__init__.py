"""Layer-1 Pallas kernels — the paper's compute hot-spot.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode lowers them to plain HLO that the Rust
runtime (xla_extension 0.5.1) executes. Block shapes are still chosen for the
TPU VMEM/MXU budget (see DESIGN.md "Hardware Adaptation"); correctness is
checked against the pure-jnp oracles in ``ref.py``.
"""

from .matmul import matmul, matmul_tiled
from .newton_schulz import newton_schulz5
from .orth import jacobi_eigh, orth_svd

__all__ = [
    "matmul",
    "matmul_tiled",
    "newton_schulz5",
    "orth_svd",
    "jacobi_eigh",
]
