"""Exact SVD moment-orthogonalization — SUMO's Block 2 as a Pallas kernel.

``orth_svd(M)`` computes the polar factor ``(M M^T)^{-1/2} M`` *exactly* (to
float precision) via a cyclic Jacobi eigendecomposition of the r x r Gram
matrix, entirely inside one Pallas block:

  * the r x n moment block and the r x r Gram live in VMEM for every rank
    the paper uses (r <= 512);
  * the Jacobi sweeps are O(r^3) VPU work — *no* HBM traffic, versus
    Newton-Schulz5's five rounds of full-matrix matmuls;
  * the final (M M^T)^{-1/2} @ M is one MXU pass.

This is the TPU re-thinking of the paper's CUDA claim that "exact SVD is
affordable in the subspace" (Remark 3.7).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Relative eigenvalue floor for pseudo-inverse behaviour on rank-deficient
# moments (matches rust/src/linalg/orth.rs EPS_REL).
_EPS_REL = 1e-10
_DEFAULT_SWEEPS = 12


def pair_indices(r: int):
    """Static (p, q) index arrays for the cyclic Jacobi sweep order."""
    ps_np, qs_np = np.triu_indices(r, 1)
    return (
        jnp.asarray(ps_np, dtype=jnp.int32),
        jnp.asarray(qs_np, dtype=jnp.int32),
    )


def jacobi_eigh(b, sweeps: int = _DEFAULT_SWEEPS, pairs=None):
    """Cyclic Jacobi eigendecomposition of a symmetric matrix.

    Returns (eigenvalues desc, eigenvectors in columns). The sweep runs in a
    bounded fori_loop; the pair rotations inside a sweep are statically
    unrolled (static indices only). The dynamic-index formulation
    (fori_loop over pairs + gather/scatter) mis-executes on xla_extension
    0.5.1's CPU runtime — the AOT consumer — so static unrolling is
    correctness-critical here, and is also what a Mosaic/TPU lowering would
    do for these tiny O(r²) rotation schedules.

    ``pairs`` is accepted for API compatibility and ignored (indices are
    compile-time constants).
    """
    del pairs
    r = b.shape[0]
    if r == 1:
        return b[0], jnp.ones((1, 1), b.dtype)
    ps_np, qs_np = np.triu_indices(r, 1)

    def sweep_body(_, carry):
        a, v = carry
        for p, q in zip(ps_np.tolist(), qs_np.tolist()):
            app = a[p, p]
            aqq = a[q, q]
            apq = a[p, q]
            small = jnp.abs(apq) < 1e-30
            tau = (aqq - app) / (2.0 * jnp.where(small, 1.0, apq))
            t = jnp.sign(tau) / (jnp.abs(tau) + jnp.sqrt(1.0 + tau * tau))
            t = jnp.where(tau == 0.0, 1.0, t)
            c = 1.0 / jnp.sqrt(1.0 + t * t)
            s = t * c
            c = jnp.where(small, 1.0, c)
            s = jnp.where(small, 0.0, s)
            rp = a[p, :]
            rq = a[q, :]
            a = a.at[p, :].set(c * rp - s * rq).at[q, :].set(s * rp + c * rq)
            cp = a[:, p]
            cq = a[:, q]
            a = a.at[:, p].set(c * cp - s * cq).at[:, q].set(s * cp + c * cq)
            vp = v[:, p]
            vq = v[:, q]
            v = v.at[:, p].set(c * vp - s * vq).at[:, q].set(s * vp + c * vq)
        return (a, v)

    a, v = jax.lax.fori_loop(
        0, sweeps, sweep_body, (b.astype(jnp.float32), jnp.eye(r, dtype=jnp.float32))
    )
    w = jnp.diagonal(a)
    order = jnp.argsort(-w)
    return w[order], v[:, order]


def _polar_from_block(m, sweeps, pairs=None):
    """(M M^T)^{-1/2} M for one VMEM-resident block (r <= n)."""
    gram = jnp.dot(m, m.T, preferred_element_type=jnp.float32)
    w, v = jacobi_eigh(gram, sweeps, pairs=pairs)
    lam_max = jnp.maximum(w[0], 0.0)
    inv = jnp.where(
        w > _EPS_REL * lam_max, 1.0 / jnp.sqrt(jnp.maximum(w, 1e-38)), 0.0
    )
    inv_sqrt = (v * inv[None, :]) @ v.T
    return jnp.dot(inv_sqrt, m, preferred_element_type=jnp.float32)


def _orth_kernel(m_ref, o_ref, *, sweeps):
    o_ref[...] = _polar_from_block(m_ref[...], sweeps)


@functools.partial(jax.jit, static_argnames=("sweeps", "interpret"))
def orth_svd(m, sweeps: int = _DEFAULT_SWEEPS, interpret: bool = True):
    """Exact Orthogonalization_SVD(M): the closest (semi-)orthogonal matrix
    in Frobenius norm. Transpose convention applied so the smaller side is
    orthonormalized (as in the paper: "either O^T O = I or O O^T = I")."""
    r, n = m.shape
    if r > n:
        return orth_svd(m.T, sweeps=sweeps, interpret=interpret).T
    if r == 1:
        # Degenerate rank-1 moment: polar factor is the normalized row.
        norm = jnp.maximum(jnp.sqrt(jnp.sum(m * m)), 1e-30)
        return (m / norm).astype(jnp.float32)
    kernel = functools.partial(_orth_kernel, sweeps=sweeps)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((r, n), jnp.float32),
        interpret=interpret,
    )(m.astype(jnp.float32))
