"""Layer-2: LLaMA-style transformer forward/backward in JAX.

Architecture (matches the paper's LLaMA family, scaled — see DESIGN.md §3):
RMSNorm -> RoPE multi-head causal attention -> RMSNorm -> SwiGLU MLP, with
tied input/output embeddings. MLP projections route through the Layer-1
Pallas ``matmul`` kernel (custom-VJP) so the kernel lowers into the same
train-step HLO the Rust runtime executes.

Parameter registration order is defined by ``param_specs`` and mirrored
exactly by ``rust/src/config/model_cfg.rs``; the AOT manifest carries the
spec list so the Rust integration tests can assert agreement.

``train_step(params, inputs, targets) -> (loss, *grads)`` and the eval/
predict variants are the functions ``aot.py`` lowers to HLO text.
"""

import jax
import jax.numpy as jnp

from .kernels import matmul

# Special token ids (mirrors rust/src/data/corpus.rs).
PAD, BOS, EOS = 0, 1, 2

# Named presets — MUST mirror rust/src/config/model_cfg.rs::preset.
PRESETS = {
    "nano": dict(vocab=256, d_model=64, n_layers=2, n_heads=4, seq_len=32),
    "micro": dict(vocab=512, d_model=128, n_layers=3, n_heads=4, seq_len=64),
    "mini": dict(vocab=1024, d_model=192, n_layers=4, n_heads=6, seq_len=64),
    "small": dict(vocab=2048, d_model=256, n_layers=6, n_heads=8, seq_len=128),
}


def d_ff_for(d_model: int) -> int:
    """SwiGLU hidden width: (8/3)·d rounded up to a multiple of 16
    (mirrors the Rust preset arithmetic)."""
    return (8 * d_model // 3 + 15) // 16 * 16


def resolve(preset: str, head: str = "lm") -> dict:
    cfg = dict(PRESETS[preset])
    cfg["d_ff"] = d_ff_for(cfg["d_model"])
    cfg["name"] = preset
    cfg["head"] = head  # "lm" | "clsK" | "reg"
    return cfg


def param_specs(cfg: dict):
    """(name, rows, cols) in registration order — the Rust twin of
    ModelCfg::param_specs."""
    d = cfg["d_model"]
    specs = [("embed", cfg["vocab"], d)]
    for l in range(cfg["n_layers"]):
        specs += [
            (f"l{l}.attn_norm", 1, d),
            (f"l{l}.wq", d, d),
            (f"l{l}.wk", d, d),
            (f"l{l}.wv", d, d),
            (f"l{l}.wo", d, d),
            (f"l{l}.mlp_norm", 1, d),
            (f"l{l}.w_gate", d, cfg["d_ff"]),
            (f"l{l}.w_up", d, cfg["d_ff"]),
            (f"l{l}.w_down", cfg["d_ff"], d),
        ]
    specs.append(("final_norm", 1, d))
    head = cfg["head"]
    if head.startswith("cls"):
        specs.append(("head", d, int(head[3:])))
    elif head == "reg":
        specs.append(("head", d, 1))
    return specs


# --------------------------------------------------------------------------
# building blocks
# --------------------------------------------------------------------------


def rmsnorm(x, scale):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * scale.reshape(-1)


def rope_tables(seq_len: int, head_dim: int):
    """cos/sin tables, shape (seq, head_dim/2)."""
    half = head_dim // 2
    inv_freq = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    t = jnp.arange(seq_len, dtype=jnp.float32)
    freqs = jnp.outer(t, inv_freq)
    return jnp.cos(freqs), jnp.sin(freqs)


def apply_rope(x, cos, sin):
    """x: (b, h, s, hd), split-halves convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[None, None, :, :]
    s = sin[None, None, :, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def attention(x, wq, wk, wv, wo, n_heads: int, cos, sin):
    b, s, d = x.shape
    hd = d // n_heads
    xf = x.reshape(b * s, d)
    q = (xf @ wq).reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
    k = (xf @ wk).reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
    v = (xf @ wv).reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(causal[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b * s, d)
    return (ctx @ wo).reshape(b, s, d)


def swiglu(x, w_gate, w_up, w_down):
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    g = matmul(xf, w_gate)
    u = matmul(xf, w_up)
    h = jax.nn.silu(g) * u
    return matmul(h, w_down).reshape(b, s, d)


def backbone(params: dict, cfg: dict, tokens):
    """tokens: (b, s) int32 -> hidden states (b, s, d)."""
    d = cfg["d_model"]
    h = params["embed"][tokens]  # gather
    cos, sin = rope_tables(tokens.shape[1], d // cfg["n_heads"])
    for l in range(cfg["n_layers"]):
        h = h + attention(
            rmsnorm(h, params[f"l{l}.attn_norm"]),
            params[f"l{l}.wq"],
            params[f"l{l}.wk"],
            params[f"l{l}.wv"],
            params[f"l{l}.wo"],
            cfg["n_heads"],
            cos,
            sin,
        )
        h = h + swiglu(
            rmsnorm(h, params[f"l{l}.mlp_norm"]),
            params[f"l{l}.w_gate"],
            params[f"l{l}.w_up"],
            params[f"l{l}.w_down"],
        )
    return rmsnorm(h, params["final_norm"])


def lm_loss(params: dict, cfg: dict, tokens, targets):
    """Mean next-token cross-entropy, PAD targets masked."""
    h = backbone(params, cfg, tokens)  # (b, s, d)
    b, s, d = h.shape
    logits = h.reshape(b * s, d) @ params["embed"].T  # tied head
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt = targets.reshape(b * s)
    nll = -jnp.take_along_axis(logp, tgt[:, None], axis=-1)[:, 0]
    mask = (tgt != PAD).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def pooled(params: dict, cfg: dict, tokens):
    """Mean-pooled final hidden state over non-PAD positions."""
    h = backbone(params, cfg, tokens)
    mask = (tokens != PAD).astype(jnp.float32)[..., None]
    return jnp.sum(h * mask, axis=1) / jnp.maximum(jnp.sum(mask, axis=1), 1.0)


def cls_logits(params: dict, cfg: dict, tokens):
    return pooled(params, cfg, tokens) @ params["head"]


def cls_loss(params: dict, cfg: dict, tokens, labels):
    logits = cls_logits(params, cfg, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    lab = labels.astype(jnp.int32)
    return -jnp.mean(jnp.take_along_axis(logp, lab[:, None], axis=-1))


def reg_loss(params: dict, cfg: dict, tokens, scores):
    pred = cls_logits(params, cfg, tokens)[:, 0]
    return jnp.mean((pred - scores) ** 2)


# --------------------------------------------------------------------------
# lowered entry points
# --------------------------------------------------------------------------


def _params_from_flat(cfg, flat):
    names = [name for name, _, _ in param_specs(cfg)]
    assert len(names) == len(flat), (len(names), len(flat))
    return dict(zip(names, flat))


def make_train_step(cfg: dict):
    """(params..., tokens, labels) -> (loss, *grads) for this config."""
    head = cfg["head"]

    def loss_fn(flat_params, tokens, labels):
        params = _params_from_flat(cfg, flat_params)
        if head == "lm":
            return lm_loss(params, cfg, tokens, labels)
        if head == "reg":
            return reg_loss(params, cfg, tokens, labels)
        return cls_loss(params, cfg, tokens, labels)

    def step(*args):
        n = len(param_specs(cfg))
        flat, tokens, labels = list(args[:n]), args[n], args[n + 1]
        loss, grads = jax.value_and_grad(loss_fn)(flat, tokens, labels)
        return (loss, *grads)

    return step


def make_eval_step(cfg: dict):
    """(params..., tokens, labels) -> (loss,) for LM; (loss, logits) for
    cls/reg heads so Rust computes accuracy / F1 / Pearson."""
    head = cfg["head"]

    def step(*args):
        n = len(param_specs(cfg))
        flat, tokens, labels = list(args[:n]), args[n], args[n + 1]
        params = _params_from_flat(cfg, flat)
        if head == "lm":
            return (lm_loss(params, cfg, tokens, labels),)
        logits = cls_logits(params, cfg, tokens)
        if head == "reg":
            loss = jnp.mean((logits[:, 0] - labels) ** 2)
        else:
            logp = jax.nn.log_softmax(logits, axis=-1)
            lab = labels.astype(jnp.int32)
            loss = -jnp.mean(jnp.take_along_axis(logp, lab[:, None], axis=-1))
        return (loss, logits)

    return step


def make_logits_step(cfg: dict):
    """(params..., tokens) -> (last-position LM logits,) for greedy decoding
    in the math-reasoning evals."""

    def step(*args):
        n = len(param_specs(cfg))
        flat, tokens = list(args[:n]), args[n]
        params = _params_from_flat(cfg, flat)
        h = backbone(params, cfg, tokens)  # (b, s, d)
        last = h[:, -1, :]
        return (last @ params["embed"].T,)

    return step


def example_args(cfg: dict, batch: int):
    """ShapeDtypeStructs for lowering: params, tokens, labels."""
    flat = [
        jax.ShapeDtypeStruct((m, n), jnp.float32) for _, m, n in param_specs(cfg)
    ]
    tokens = jax.ShapeDtypeStruct((batch, cfg["seq_len"]), jnp.int32)
    head = cfg["head"]
    if head == "lm":
        labels = jax.ShapeDtypeStruct((batch, cfg["seq_len"]), jnp.int32)
    elif head == "reg":
        labels = jax.ShapeDtypeStruct((batch,), jnp.float32)
    else:
        labels = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return flat, tokens, labels
